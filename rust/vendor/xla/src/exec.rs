//! Graph execution: `PjRtClient::compile` + the host interpreter behind
//! `PjRtLoadedExecutable::execute`.

use std::borrow::Borrow;

use crate::builder::{CompKind, Node, Op, XlaComputation};
use crate::literal::Data;
use crate::{ElementType, Error, Literal, Result};

/// Handle to the (host) execution backend.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match &comp.kind {
            CompKind::Graph { nodes, root } => Ok(PjRtLoadedExecutable {
                nodes: nodes.clone(),
                root: *root,
            }),
            CompKind::External { path } => Err(Error::new(format!(
                "the host-interpreter stub cannot execute AOT HLO artifacts ({path}); \
                 link the native xla crate for artifact execution"
            ))),
        }
    }
}

/// A compiled (snapshot) graph. Owns plain data: `Send + Sync`, safe to
/// share across mask-engine worker threads behind `Arc`.
pub struct PjRtLoadedExecutable {
    nodes: Vec<Node>,
    root: usize,
}

/// Device buffer stand-in; already host-resident here.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

impl PjRtLoadedExecutable {
    /// Interpret the graph over the argument literals. Deterministic: the
    /// same executable on the same inputs always produces identical bits.
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let live = self.reachable();
        let mut values: Vec<Option<Literal>> = vec![None; self.nodes.len()];
        for id in 0..self.nodes.len() {
            if !live[id] {
                continue;
            }
            let lit = self.eval_node(id, &values, args)?;
            values[id] = Some(lit);
        }
        let root = values[self.root]
            .take()
            .ok_or_else(|| Error::new("root was not evaluated"))?;
        Ok(vec![vec![PjRtBuffer { lit: root }]])
    }

    fn reachable(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack = vec![self.root];
        while let Some(id) = stack.pop() {
            if live[id] {
                continue;
            }
            live[id] = true;
            stack.extend(self.nodes[id].inputs.iter().copied());
        }
        live
    }

    fn input<'a>(
        &self,
        values: &'a [Option<Literal>],
        node: &Node,
        which: usize,
    ) -> Result<&'a Literal> {
        values[node.inputs[which]]
            .as_ref()
            .ok_or_else(|| Error::new("input evaluated out of order"))
    }

    fn eval_node<L: Borrow<Literal>>(
        &self,
        id: usize,
        values: &[Option<Literal>],
        args: &[L],
    ) -> Result<Literal> {
        let node = &self.nodes[id];
        match &node.op {
            Op::Parameter(i) => {
                let arg: &Literal = args
                    .get(*i)
                    .map(|l| l.borrow())
                    .ok_or_else(|| {
                        Error::new(format!("missing argument {i} (got {})", args.len()))
                    })?;
                if arg.dims != node.dims {
                    return Err(Error::new(format!(
                        "argument {i} has dims {:?}, graph expects {:?}",
                        arg.dims, node.dims
                    )));
                }
                if arg.element_type() != Some(node.ty) {
                    return Err(Error::new(format!(
                        "argument {i} has type {:?}, graph expects {:?}",
                        arg.element_type(),
                        node.ty
                    )));
                }
                Ok(arg.clone())
            }
            Op::ConstF32(v) => Ok(Literal::scalar(*v)),
            Op::Iota { dim } => Ok(iota(node, *dim)),
            Op::Dot { lhs_c, rhs_c } => {
                let a = self.input(values, node, 0)?;
                let b = self.input(values, node, 1)?;
                dot(a, b, *lhs_c, *rhs_c, &node.dims)
            }
            Op::Add => self.arith(values, node, |x, y| x + y),
            Op::Sub => self.arith(values, node, |x, y| x - y),
            Op::Mul => self.arith(values, node, |x, y| x * y),
            Op::Div => self.arith(values, node, |x, y| x / y),
            Op::Eq => {
                let a = self.input(values, node, 0)?;
                let b = self.input(values, node, 1)?;
                eq(a, b, &node.dims)
            }
            Op::Convert => {
                let a = self.input(values, node, 0)?;
                convert(a, node.ty)
            }
            Op::ReduceSum { dims, keep } => {
                let a = self.input(values, node, 0)?;
                reduce_sum(a, dims, *keep)
            }
            Op::Sqrt => {
                let a = self.input(values, node, 0)?;
                let data: Vec<f32> = a.f32s()?.iter().map(|x| x.sqrt()).collect();
                Ok(Literal {
                    dims: a.dims.clone(),
                    data: Data::F32(data),
                })
            }
            Op::Tuple => {
                let elems: Result<Vec<Literal>> = (0..node.inputs.len())
                    .map(|j| self.input(values, node, j).cloned())
                    .collect();
                Ok(Literal::tuple(elems?))
            }
        }
    }

    fn arith(
        &self,
        values: &[Option<Literal>],
        node: &Node,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<Literal> {
        let a = self.input(values, node, 0)?.f32s()?;
        let b = self.input(values, node, 1)?.f32s()?;
        let data = if a.len() == b.len() {
            a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect()
        } else if a.len() == 1 {
            b.iter().map(|&y| f(a[0], y)).collect()
        } else if b.len() == 1 {
            a.iter().map(|&x| f(x, b[0])).collect()
        } else {
            return Err(Error::new("elementwise length mismatch at execute time"));
        };
        Ok(Literal {
            dims: node.dims.clone(),
            data: Data::F32(data),
        })
    }
}

fn iota(node: &Node, dim: usize) -> Literal {
    let dims_us: Vec<usize> = node.dims.iter().map(|&d| d as usize).collect();
    let n: usize = dims_us.iter().product();
    // row-major stride of the iota dimension
    let stride: usize = dims_us[dim + 1..].iter().product();
    let extent = dims_us[dim];
    let data = match node.ty {
        ElementType::S32 => {
            Data::S32((0..n).map(|i| ((i / stride) % extent) as i32).collect())
        }
        ElementType::F32 => {
            Data::F32((0..n).map(|i| ((i / stride) % extent) as f32).collect())
        }
        ElementType::Pred => Data::Pred(vec![false; n]),
    };
    Literal {
        dims: node.dims.clone(),
        data,
    }
}

/// 2-D dot: normalize both operands to standard (m,k) x (k,n) layout,
/// then a cache-friendly ikj kernel.
fn dot(a: &Literal, b: &Literal, lhs_c: usize, rhs_c: usize, out_dims: &[i64]) -> Result<Literal> {
    let (m, n) = (out_dims[0] as usize, out_dims[1] as usize);
    let k = a.dims[lhs_c] as usize;
    let a_std = to_standard(a.f32s()?, a.dims[0] as usize, a.dims[1] as usize, lhs_c == 0);
    let b_std = to_standard(b.f32s()?, b.dims[0] as usize, b.dims[1] as usize, rhs_c == 1);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a_std[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b_std[l * n..(l + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Ok(Literal {
        dims: out_dims.to_vec(),
        data: Data::F32(out),
    })
}

/// Copy a (r, c) row-major matrix, transposing when `transpose` is set.
fn to_standard(data: &[f32], r: usize, c: usize, transpose: bool) -> Vec<f32> {
    if !transpose {
        return data.to_vec();
    }
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = data[i * c + j];
        }
    }
    out
}

fn eq(a: &Literal, b: &Literal, out_dims: &[i64]) -> Result<Literal> {
    fn cmp<T: PartialEq + Copy>(a: &[T], b: &[T]) -> Result<Vec<bool>> {
        if a.len() == b.len() {
            Ok(a.iter().zip(b).map(|(x, y)| x == y).collect())
        } else if a.len() == 1 {
            Ok(b.iter().map(|y| *y == a[0]).collect())
        } else if b.len() == 1 {
            Ok(a.iter().map(|x| *x == b[0]).collect())
        } else {
            Err(Error::new("eq length mismatch at execute time"))
        }
    }
    let data = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => cmp(x, y)?,
        (Data::S32(x), Data::S32(y)) => cmp(x, y)?,
        (Data::Pred(x), Data::Pred(y)) => cmp(x, y)?,
        _ => return Err(Error::new("eq operand types differ at execute time")),
    };
    Ok(Literal {
        dims: out_dims.to_vec(),
        data: Data::Pred(data),
    })
}

fn convert(a: &Literal, ty: ElementType) -> Result<Literal> {
    let data = match (&a.data, ty) {
        (Data::F32(v), ElementType::F32) => Data::F32(v.clone()),
        (Data::S32(v), ElementType::F32) => Data::F32(v.iter().map(|&x| x as f32).collect()),
        (Data::Pred(v), ElementType::F32) => {
            Data::F32(v.iter().map(|&x| if x { 1.0 } else { 0.0 }).collect())
        }
        (Data::F32(v), ElementType::S32) => Data::S32(v.iter().map(|&x| x as i32).collect()),
        (Data::S32(v), ElementType::S32) => Data::S32(v.clone()),
        (Data::Pred(v), ElementType::S32) => {
            Data::S32(v.iter().map(|&x| i32::from(x)).collect())
        }
        _ => return Err(Error::new(format!("unsupported convert to {ty:?}"))),
    };
    Ok(Literal {
        dims: a.dims.clone(),
        data,
    })
}

fn reduce_sum(a: &Literal, reduce: &[usize], keep: bool) -> Result<Literal> {
    let vals = a.f32s()?;
    let in_dims: Vec<usize> = a.dims.iter().map(|&d| d as usize).collect();
    let mut out_dims_us = Vec::new();
    for (i, &d) in in_dims.iter().enumerate() {
        if reduce.contains(&i) {
            if keep {
                out_dims_us.push(1);
            }
        } else {
            out_dims_us.push(d);
        }
    }
    let out_n: usize = out_dims_us.iter().product::<usize>().max(1);
    let mut acc = vec![0.0f64; out_n];
    // row-major strides of the input
    let mut strides = vec![1usize; in_dims.len()];
    for i in (0..in_dims.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * in_dims[i + 1];
    }
    for (flat, &v) in vals.iter().enumerate() {
        // output flat index: row-major over the kept dims
        let mut out_flat = 0usize;
        for (i, (&d, &s)) in in_dims.iter().zip(&strides).enumerate() {
            let idx = (flat / s) % d;
            // reduced dims contribute extent 1 (kept) or nothing (dropped)
            if !reduce.contains(&i) {
                out_flat = out_flat * d + idx;
            }
        }
        acc[out_flat] += v as f64;
    }
    Ok(Literal {
        dims: out_dims_us.iter().map(|&d| d as i64).collect(),
        data: Data::F32(acc.into_iter().map(|x| x as f32).collect()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{ElementType, PrimitiveType, XlaBuilder};

    fn lit2(r: usize, c: usize, v: &[f32]) -> Literal {
        Literal::vec1(v).reshape(&[r as i64, c as i64]).unwrap()
    }

    #[test]
    fn dot_matches_hand_result() {
        let bld = XlaBuilder::new("t");
        let a = bld.parameter(0, ElementType::F32, &[2, 3], "a").unwrap();
        let b = bld.parameter(1, ElementType::F32, &[3, 2], "b").unwrap();
        let c = a.dot_general(&b, &[1], &[0], &[], &[]).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&c.build().unwrap()).unwrap();
        let la = lit2(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let lb = lit2(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let out = exe.execute(&[&la, &lb]).unwrap();
        let lit = out[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![58., 64., 139., 154.]);
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
    }

    #[test]
    fn transposed_dots_agree() {
        // a^T b and a b^T variants against the standard layout
        let bld = XlaBuilder::new("t");
        let at = bld.parameter(0, ElementType::F32, &[3, 2], "at").unwrap();
        let b = bld.parameter(1, ElementType::F32, &[3, 2], "b").unwrap();
        let c = at.dot_general(&b, &[0], &[0], &[], &[]).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&c.build().unwrap()).unwrap();
        // at = a^T where a = [[1,2,3],[4,5,6]]
        let lat = lit2(3, 2, &[1., 4., 2., 5., 3., 6.]);
        let lb = lit2(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let out = exe.execute(&[&lat, &lb]).unwrap();
        let lit = out[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![58., 64., 139., 154.]);
    }

    #[test]
    fn iota_eq_convert_builds_identity() {
        let bld = XlaBuilder::new("t");
        let rows = bld.iota(ElementType::S32, &[3, 3], 0).unwrap();
        let cols = bld.iota(ElementType::S32, &[3, 3], 1).unwrap();
        let eye = rows.eq(&cols).unwrap().convert(PrimitiveType::F32).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&eye.build().unwrap()).unwrap();
        let out = exe.execute::<Literal>(&[]).unwrap();
        let lit = out[0][0].to_literal_sync().unwrap();
        assert_eq!(
            lit.to_vec::<f32>().unwrap(),
            vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]
        );
    }

    #[test]
    fn scalar_broadcast_and_reduce() {
        let bld = XlaBuilder::new("t");
        let a = bld.parameter(0, ElementType::F32, &[2, 2], "a").unwrap();
        let total = a.reduce_sum(&[0, 1], false).unwrap();
        let scaled = (&a / &total).unwrap();
        let root = bld.tuple(&[total, scaled]).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&root.build().unwrap()).unwrap();
        let la = lit2(2, 2, &[1., 2., 3., 4.]);
        let out = exe.execute(&[&la]).unwrap();
        let mut lit = out[0][0].to_literal_sync().unwrap();
        let parts = lit.decompose_tuple().unwrap();
        assert_eq!(parts[0].get_first_element::<f32>().unwrap(), 10.0);
        assert_eq!(parts[0].array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn sqrt_and_sub_chain() {
        let bld = XlaBuilder::new("t");
        let a = bld.parameter(0, ElementType::F32, &[3], "a").unwrap();
        let shifted = (&a - bld.c0(1.0).unwrap()).unwrap();
        let root = shifted.sqrt().unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&root.build().unwrap()).unwrap();
        let la = Literal::vec1(&[1.0f32, 5.0, 10.0]);
        let out = exe.execute(&[&la]).unwrap();
        let got = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(got, vec![0.0, 2.0, 3.0]);
    }

    #[test]
    fn bad_args_are_rejected() {
        let bld = XlaBuilder::new("t");
        let a = bld.parameter(0, ElementType::F32, &[2, 2], "a").unwrap();
        let exe = PjRtClient::cpu()
            .unwrap()
            .compile(&a.sqrt().unwrap().build().unwrap())
            .unwrap();
        let wrong = Literal::vec1(&[1.0f32, 2.0]);
        assert!(exe.execute(&[&wrong]).is_err());
        assert!(exe.execute::<Literal>(&[]).is_err());
    }

    #[test]
    fn executables_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
    }
}

//! Host literals: shaped, typed host buffers (plus tuples of them).

use crate::{ElementType, Error, Result};

/// Typed storage behind a literal.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Pred(Vec<bool>),
    Tuple(Vec<Literal>),
}

impl Data {
    pub(crate) fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
            Data::Pred(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    pub(crate) fn element_type(&self) -> Option<ElementType> {
        match self {
            Data::F32(_) => Some(ElementType::F32),
            Data::S32(_) => Some(ElementType::S32),
            Data::Pred(_) => Some(ElementType::Pred),
            Data::Tuple(_) => None,
        }
    }
}

/// Shaped host value; the interchange type between the coordinator and
/// compiled executables.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    pub(crate) dims: Vec<i64>,
    pub(crate) data: Data,
}

/// Array shape (dims + implicit element type) of a non-tuple literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Rust scalar types that map onto literal element types.
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(v: Vec<i32>) -> Data {
        Data::S32(v)
    }

    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: vec![],
            data: T::wrap(vec![v]),
        }
    }

    /// Same data, new dims (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("reshape on a tuple literal"));
        }
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("array_shape on a tuple literal"));
        }
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Flat host copy of the elements (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error::new(format!(
                "to_vec: literal holds {:?}, requested {:?}",
                self.data.element_type(),
                T::TY
            ))
        })
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first()
            .copied()
            .ok_or_else(|| Error::new("get_first_element on an empty literal"))
    }

    /// Split a tuple literal into its elements (consumes the contents).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(Vec::new())) {
            Data::Tuple(elems) => Ok(elems),
            other => {
                self.data = other;
                Err(Error::new("decompose_tuple on a non-tuple literal"))
            }
        }
    }

    pub(crate) fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elems.len() as i64],
            data: Data::Tuple(elems),
        }
    }

    pub(crate) fn element_type(&self) -> Option<ElementType> {
        self.data.element_type()
    }

    pub(crate) fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(Error::new("expected an f32 literal")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalars_and_type_mismatch() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        assert!(s.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decomposes_once() {
        let mut t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let mut plain = Literal::scalar(0.0f32);
        assert!(plain.decompose_tuple().is_err());
        assert_eq!(plain.get_first_element::<f32>().unwrap(), 0.0);
    }
}

//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the workspace vendors
//! the exact error-handling surface it uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream anyhow where observable from this
//! codebase:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (the source chain is flattened into the message, `": "`-joined);
//! * `context`/`with_context` wrap outside-in (`"ctx: cause"`);
//! * `Error` itself does NOT implement `std::error::Error` — exactly like
//!   upstream — which is what keeps the blanket `From` impl coherent.

use std::fmt;

/// String-backed error with anyhow-style context chaining.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with outer context: `"{c}: {self}"`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_wraps_outside_in() {
        let e: Result<()> = Err(io_err()).context("opening file");
        assert_eq!(e.unwrap_err().to_string(), "opening file: gone");
        let o: Result<i32> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(o.unwrap_err().to_string(), "missing 7");
    }

    #[test]
    fn context_composes_on_anyhow_results() {
        let e: Result<()> = Err(anyhow!("inner {}", 1));
        let e = e.context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: inner 1");
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }
}

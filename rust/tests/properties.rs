//! Property-based tests over coordinator invariants (util::prop is the
//! offline stand-in for proptest; failing seeds are printed for replay).

use lift::data::tasks::{gen_sample, samples_to_batches, TaskFamily};
use lift::data::{Kg, Vocab};
use lift::exp::grid::{Axis, Grid};
use lift::exp::matrix::CellSpec;
use lift::lift::{budget_for, mask_overlap, topk_indices};
use lift::model;
use lift::optim::{AdamCfg, DenseAdam, SparseAdam};
use lift::tensor::Tensor;
use lift::util::eigh;
use lift::util::json::Json;
use lift::util::prop::{check, ensure, ensure_close, gen_size};
use lift::util::rng::Rng;
use lift::util::stats;

#[test]
fn prop_topk_selects_exactly_k_largest() {
    check("topk exact-k and dominance", |rng| {
        let n = gen_size(rng, 2, 400);
        let k = 1 + rng.below(n);
        let vals = rng.normal_vec(n, 1.0);
        let idx = topk_indices(&vals, k);
        ensure(idx.len() == k, format!("got {} wanted {k}", idx.len()))?;
        // dominance: min |selected| >= max |unselected|
        let sel: std::collections::HashSet<u32> = idx.iter().copied().collect();
        let min_in = idx
            .iter()
            .map(|&i| vals[i as usize].abs())
            .fold(f32::MAX, f32::min);
        let max_out = (0..n as u32)
            .filter(|i| !sel.contains(i))
            .map(|i| vals[i as usize].abs())
            .fold(0.0f32, f32::max);
        ensure(
            min_in >= max_out,
            format!("dominance violated: {min_in} < {max_out}"),
        )
    });
}

#[test]
fn prop_topk_threshold_matches_sort_oracle() {
    // quickselect (select_nth_unstable) against a full-sort oracle, over
    // random sizes, heavy ties (quantized values), and k in {1, .., n}
    check("quickselect == sort oracle", |rng| {
        let n = gen_size(rng, 1, 500);
        let quantize = rng.chance(0.5);
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                let x = rng.normal();
                if quantize {
                    (x * 2.0).round() / 2.0 // many exact ties incl. 0.0
                } else {
                    x
                }
            })
            .collect();
        for k in [1, 1 + rng.below(n), n] {
            let thr = stats::topk_abs_threshold(&vals, k);
            let mut mags: Vec<f32> = vals.iter().map(|x| x.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let oracle = mags[k - 1];
            ensure(
                thr == oracle,
                format!("n={n} k={k}: quickselect {thr} != sorted {oracle}"),
            )?;
            // contract: at least k entries clear the threshold
            let at_or_above = vals.iter().filter(|x| x.abs() >= thr).count();
            ensure(
                at_or_above >= k,
                format!("n={n} k={k}: only {at_or_above} entries >= {thr}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_topk_indices_edge_ks_and_ties() {
    // topk_indices must return exactly k sorted unique indices for
    // k in {0, 1, n} and under ties, and selection must dominate:
    // min |selected| >= max |unselected|
    check("topk indices edges + ties", |rng| {
        let n = gen_size(rng, 1, 300);
        // quantized values force tie-trimming at the threshold
        let vals: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0).round()).collect();
        for k in [0, 1, 1 + rng.below(n), n] {
            let idx = topk_indices(&vals, k);
            ensure(idx.len() == k, format!("k={k}: got {}", idx.len()))?;
            ensure(
                idx.windows(2).all(|w| w[0] < w[1]),
                format!("k={k}: indices not sorted/unique"),
            )?;
            let sel: std::collections::HashSet<u32> = idx.iter().copied().collect();
            let min_in = idx
                .iter()
                .map(|&i| vals[i as usize].abs())
                .fold(f32::MAX, f32::min);
            let max_out = (0..n as u32)
                .filter(|i| !sel.contains(i))
                .map(|i| vals[i as usize].abs())
                .fold(0.0f32, f32::max);
            if k > 0 && k < n {
                ensure(
                    min_in >= max_out,
                    format!("k={k}: dominance violated ({min_in} < {max_out})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_budget_is_monotone_and_capped() {
    check("budget monotone/capped", |rng| {
        let m = gen_size(rng, 2, 512);
        let n = gen_size(rng, 2, 512);
        let r1 = gen_size(rng, 1, 128);
        let r2 = r1 + gen_size(rng, 1, 64);
        let b1 = budget_for(m, n, r1);
        let b2 = budget_for(m, n, r2);
        ensure(b1 <= b2, "monotonicity")?;
        ensure(b2 <= (m * n / 2).max(1), "cap")?;
        ensure(b1 >= 1, "positive")
    });
}

#[test]
fn prop_sparse_adam_touches_only_mask() {
    check("sparse adam mask confinement", |rng| {
        let n = gen_size(rng, 4, 300);
        let k = 1 + rng.below(n / 2 + 1);
        let idx: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        let mut w = rng.normal_vec(n, 1.0);
        let w0 = w.clone();
        let g = rng.normal_vec(n, 1.0);
        let mut opt = SparseAdam::new(idx.clone(), AdamCfg::default());
        for _ in 0..3 {
            opt.step(&mut w, &g, 1e-2);
        }
        let sel: std::collections::HashSet<u32> = idx.iter().copied().collect();
        for i in 0..n {
            let moved = w[i] != w0[i];
            if sel.contains(&(i as u32)) {
                // gradient nonzero a.s. -> must move
                ensure(moved, format!("masked {i} frozen"))?;
            } else {
                ensure(!moved, format!("unmasked {i} moved"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_adam_refresh_preserves_intersection() {
    check("refresh state migration", |rng| {
        let n = 200;
        let k = 20 + rng.below(30);
        let idx: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        let mut opt = SparseAdam::new(idx.clone(), AdamCfg::default());
        let mut w = rng.normal_vec(n, 1.0);
        let g = rng.normal_vec(n, 1.0);
        opt.step(&mut w, &g, 1e-2);
        let before: std::collections::HashMap<u32, (f32, f32)> = opt
            .idx
            .iter()
            .enumerate()
            .map(|(j, &i)| (i, (opt.m[j], opt.v[j])))
            .collect();
        let new_idx: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        opt.refresh(new_idx.clone());
        for (j, &i) in opt.idx.iter().enumerate() {
            match before.get(&i) {
                Some(&(m, v)) => {
                    ensure(opt.m[j] == m && opt.v[j] == v, "survivor state changed")?
                }
                None => ensure(
                    opt.m[j] == 0.0 && opt.v[j] == 0.0,
                    "newcomer state not cold",
                )?,
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dense_equals_sparse_on_full_mask() {
    check("dense == sparse(full mask)", |rng| {
        let n = gen_size(rng, 2, 120);
        let mut w1 = rng.normal_vec(n, 1.0);
        let mut w2 = w1.clone();
        let mut d = DenseAdam::new(n, AdamCfg::default());
        let mut s = SparseAdam::new((0..n as u32).collect(), AdamCfg::default());
        for _ in 0..4 {
            let g = rng.normal_vec(n, 1.0);
            d.step(&mut w1, &g, 3e-3);
            s.step(&mut w2, &g, 3e-3);
        }
        for i in 0..n {
            ensure_close(w1[i] as f64, w2[i] as f64, 1e-6, "weight")?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
            4 => Json::arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1))),
            _ => Json::obj(
                (0..rng.below(4))
                    .map(|i| (["a", "b", "c", "d"][i % 4], gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json print->parse identity", |rng| {
        let j = gen_json(rng, 3);
        let j2 = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        ensure(j == j2, format!("{j} != {j2}"))
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_shapes() {
    check("checkpoint roundtrip", |rng| {
        let n_tensors = 1 + rng.below(6);
        let params: Vec<Tensor> = (0..n_tensors)
            .map(|_| {
                let ndim = 1 + rng.below(2);
                let shape: Vec<usize> = (0..ndim).map(|_| gen_size(rng, 1, 40)).collect();
                Tensor::randn(&shape, 1.0, rng)
            })
            .collect();
        let path = std::env::temp_dir().join(format!("lift_prop_{}.ckpt", rng.next_u64()));
        model::save_checkpoint(&path, &params).map_err(|e| e.to_string())?;
        let loaded = model::load_checkpoint(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        ensure(params == loaded, "roundtrip mismatch")
    });
}

#[test]
fn prop_task_batches_targets_are_shifted_answers() {
    let vocab = Vocab::new(512);
    let kg = Kg::new(7, vocab.n_entities, vocab.n_relations);
    let families = [
        TaskFamily::MultiArith,
        TaskFamily::AddSub,
        TaskFamily::BoolQ,
        TaskFamily::ArcC,
        TaskFamily::Qnli,
        TaskFamily::CodeGen,
    ];
    check("task batch mask/target consistency", |rng| {
        let fam = families[rng.below(families.len())];
        let s = gen_sample(fam, &vocab, &kg, rng);
        let seq = 64;
        let batches = samples_to_batches(std::slice::from_ref(&s), 4, seq);
        let (b, used) = &batches[0];
        ensure(*used == 1, "rows used")?;
        let masked: Vec<i32> = (0..seq)
            .filter(|&i| b.loss_mask[i] == 1.0)
            .map(|i| b.targets[i])
            .collect();
        ensure(
            masked == s.answer(),
            format!("{fam:?}: masked targets != answer"),
        )?;
        // every masked position's *input* context is strictly the prompt
        // prefix: position i uses tokens [0..=i], all before answer end
        for i in 0..seq {
            if b.loss_mask[i] == 1.0 {
                ensure(
                    i + 1 >= s.answer_start && i < s.answer_start + s.answer_len,
                    "mask outside answer window",
                )?;
            }
        }
        Ok(())
    });
}

/// Frobenius norm of `a - u diag(s) vt` (u m x r, vt r x n).
fn recon_err(a: &[f32], u: &[f32], s: &[f32], vt: &[f32], m: usize, n: usize, r: usize) -> f64 {
    let mut err = 0.0f64;
    for i in 0..m {
        for j in 0..n {
            let mut rec = 0.0f64;
            for c in 0..r {
                rec += u[i * r + c] as f64 * s[c] as f64 * vt[c * n + j] as f64;
            }
            let d = a[i * n + j] as f64 - rec;
            err += d * d;
        }
    }
    err.sqrt()
}

/// Truncate a full SVD (u m x rfull) to its leading r columns.
fn truncate_full(
    u: &[f32],
    s: &[f32],
    vt: &[f32],
    m: usize,
    n: usize,
    rfull: usize,
    r: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ur = vec![0.0f32; m * r];
    for i in 0..m {
        ur[i * r..(i + 1) * r].copy_from_slice(&u[i * rfull..i * rfull + r]);
    }
    (ur, s[..r].to_vec(), vt[..r * n].to_vec())
}

#[test]
fn prop_svd_topr_matches_full_oracle() {
    // the top-r subspace path against the retained full-eigh64 oracle:
    // singular values within the documented tolerance, reconstruction
    // error no worse than the oracle's best-rank-r + documented slack
    check("topr svd vs full oracle", |rng| {
        let m = gen_size(rng, 2, 64);
        let n = gen_size(rng, 2, 64);
        let minmn = m.min(n);
        let r = 1 + rng.below(minmn);
        let a = rng.normal_vec(m * n, 1.0);
        let (uf, sf, vtf) = eigh::svd(&a, m, n);
        let (u, s, vt) = eigh::svd_topr(&a, m, n, r);
        ensure(
            u.len() == m * r && s.len() == r && vt.len() == r * n,
            format!("shapes for ({m},{n}) r={r}"),
        )?;
        let smax = sf.first().copied().unwrap_or(0.0).max(1e-12);
        for c in 0..r {
            ensure(
                (s[c] - sf[c]).abs() <= eigh::TOPR_SV_TOL * smax,
                format!("({m},{n}) r={r} s[{c}]: topr {} vs oracle {}", s[c], sf[c]),
            )?;
            ensure(s[c] >= -1e-6, format!("negative singular value {}", s[c]))?;
        }
        // sorted descending (up to float noise)
        for c in 1..r {
            ensure(
                s[c - 1] >= s[c] - eigh::TOPR_SV_TOL * smax,
                format!("s not sorted at {c}: {} < {}", s[c - 1], s[c]),
            )?;
        }
        let (ur, sr, vtr) = truncate_full(&uf, &sf, &vtf, m, n, minmn, r);
        let err_topr = recon_err(&a, &u, &s, &vt, m, n, r);
        let err_oracle = recon_err(&a, &ur, &sr, &vtr, m, n, r);
        let norm = stats::l2_norm(&a);
        ensure(
            err_topr <= err_oracle + eigh::TOPR_RECON_SLACK as f64 * norm.max(1e-12),
            format!("({m},{n}) r={r}: recon {err_topr} vs oracle {err_oracle}"),
        )
    });
}

#[test]
fn prop_svd_topr_degenerate_shapes() {
    // m=1, n=1, rank 0, rank=min(m,n): shapes hold and values match the
    // oracle exactly (all of these route through the full fallback)
    check("topr degenerate shapes", |rng| {
        let n = gen_size(rng, 1, 40);
        let row = rng.normal_vec(n, 1.0);
        for (m2, n2, r2) in [(1, n, 1), (n, 1, 1)] {
            let (u, s, vt) = eigh::svd_topr(&row, m2, n2, r2);
            ensure(
                u.len() == m2 * r2 && s.len() == r2 && vt.len() == r2 * n2,
                format!("shape ({m2},{n2})"),
            )?;
            let want = stats::l2_norm(&row) as f32;
            ensure(
                (s[0] - want).abs() <= 1e-4 * want.max(1.0),
                format!("vector norm {} vs {}", s[0], want),
            )?;
        }
        let m = gen_size(rng, 2, 24);
        let k = gen_size(rng, 2, 24);
        let a = rng.normal_vec(m * k, 1.0);
        let (u, s, vt) = eigh::svd_topr(&a, m, k, 0);
        ensure(
            u.is_empty() && s.is_empty() && vt.is_empty(),
            "rank 0 must be empty",
        )?;
        let r = m.min(k);
        let (_, s_full, _) = eigh::svd(&a, m, k);
        let (_, s_topr, _) = eigh::svd_topr(&a, m, k, r);
        for c in 0..r {
            ensure(
                (s_topr[c] - s_full[c]).abs() <= 1e-4 * s_full[0].max(1.0),
                format!("full-rank topr s[{c}]"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_svd_topr_tied_singular_values() {
    // A = U diag(s) V^T with tied clusters, the truncation boundary cut
    // *inside* a cluster: singular values must still match the oracle and
    // the reconstruction must be as good (any subspace of a tied cluster
    // is equally optimal)
    check("topr with tied spectra", |rng| {
        let m = 40 + rng.below(20);
        let n = 30 + rng.below(10);
        let minmn = m.min(n);
        // orthonormal factors from QR'd gaussians (host Gram-Schmidt)
        let qa = random_orthonormal(rng, m, minmn);
        let qb = random_orthonormal(rng, n, minmn);
        // spectrum 3,3,3,3,2,2,2,2,1,1,... (ties across the r=6 cut)
        let sv: Vec<f32> = (0..minmn)
            .map(|i| if i < 4 { 3.0 } else if i < 8 { 2.0 } else { 1.0 })
            .collect();
        let mut a = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for c in 0..minmn {
                    acc += qa[i * minmn + c] as f64 * sv[c] as f64 * qb[j * minmn + c] as f64;
                }
                a[i * n + j] = acc as f32;
            }
        }
        let r = 6; // cuts inside the tied 2-cluster
        let (u, s, vt) = eigh::svd_topr(&a, m, n, r);
        for (c, want) in sv[..r].iter().enumerate() {
            ensure(
                (s[c] - want).abs() <= eigh::TOPR_SV_TOL * sv[0],
                format!("tied s[{c}]: {} vs {}", s[c], want),
            )?;
        }
        let err = recon_err(&a, &u, &s, &vt, m, n, r);
        // best rank-6 error: sqrt(2*2^2 + (minmn-8)*1^2) exactly
        let best = (2.0 * 4.0 + (minmn - 8) as f64).sqrt();
        let norm = stats::l2_norm(&a);
        ensure(
            err <= best + eigh::TOPR_RECON_SLACK as f64 * norm,
            format!("tied recon {err} vs best {best}"),
        )
    });
}

/// Random column-orthonormal matrix (m x k), built by Gram-Schmidt with
/// re-orthogonalization (f64) — the test-side oracle for tied spectra.
fn random_orthonormal(rng: &mut Rng, m: usize, k: usize) -> Vec<f32> {
    let mut cols: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..m).map(|_| rng.normal() as f64).collect())
        .collect();
    for j in 0..k {
        for _pass in 0..2 {
            for i in 0..j {
                let dot: f64 = (0..m).map(|t| cols[i][t] * cols[j][t]).sum();
                for t in 0..m {
                    let v = cols[i][t];
                    cols[j][t] -= dot * v;
                }
            }
        }
        let nrm = cols[j].iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for x in cols[j].iter_mut() {
            *x /= nrm;
        }
    }
    let mut out = vec![0.0f32; m * k];
    for (j, col) in cols.iter().enumerate() {
        for i in 0..m {
            out[i * k + j] = col[i] as f32;
        }
    }
    out
}

#[test]
fn prop_warm_refresh_stays_inside_the_cold_contract() {
    // randomized steady states: decompose, drift the matrix a little,
    // then refresh warm-started from the carrier. The warm result must
    // satisfy the SAME tolerances as a cold svd_topr of the drifted
    // matrix — the drift guard's job is to make accuracy independent of
    // how stale the carrier is.
    check("warm refresh vs cold contract", |rng| {
        let m = 40 + rng.below(25);
        let n = 40 + rng.below(25);
        let r = 2 + rng.below(4); // p = r + 8, 2p < 40 <= min(m, n)
        let mut a = rng.normal_vec(m * n, 1.0);
        let mut scratch = eigh::EighScratch::new();
        let (_, _, _, carrier) = eigh::svd_topr_warm(&a, m, n, r, None, &mut scratch);
        ensure(carrier.is_some(), "subspace path must emit a carrier")?;
        // drift, as `interval` optimizer steps would
        for x in a.iter_mut() {
            *x += rng.normal() * 0.03;
        }
        let (_, sw, _, _) = eigh::svd_topr_warm(&a, m, n, r, carrier.as_ref(), &mut scratch);
        let (_, sf, _) = eigh::svd(&a, m, n);
        let smax = sf[0].max(1e-12);
        for c in 0..r {
            ensure(
                (sw[c] - sf[c]).abs() <= eigh::TOPR_SV_TOL * smax,
                format!("warm s[{c}]: {} vs oracle {}", sw[c], sf[c]),
            )?;
        }
        // the masks a warm refresh selects match cold selection: both
        // reconstructions sit within tolerance of the oracle, so the
        // top-k of |W'| agrees on all but threshold-tie entries
        let (wr_warm, _) = eigh::lowrank_approx_warm(&a, m, n, r, carrier.as_ref(), &mut scratch);
        let (wr_cold, _) = eigh::lowrank_approx_warm(&a, m, n, r, None, &mut scratch);
        let k = budget_for(m, n, 2);
        let warm_mask = topk_indices(&wr_warm, k);
        let cold_mask = topk_indices(&wr_cold, k);
        let ov = mask_overlap(&warm_mask, &cold_mask);
        // the two factorizations agree far inside the selection margin,
        // so only entries within rounding distance of the top-k
        // threshold can flip — a handful out of k >= 150
        ensure(
            ov >= 0.95,
            format!("warm mask diverged from cold selection: overlap {ov:.4}"),
        )
    });
}

#[test]
fn prop_svd_reconstruction_error_bounded() {
    check("jacobi svd reconstructs", |rng| {
        let m = gen_size(rng, 2, 28);
        let n = gen_size(rng, 2, 28);
        let a = rng.normal_vec(m * n, 1.0);
        let (u, s, vt) = eigh::svd(&a, m, n);
        let r = m.min(n);
        let mut rec = vec![0.0f32; m * n];
        for i in 0..m {
            for c in 0..r {
                let x = u[i * r + c] * s[c];
                for j in 0..n {
                    rec[i * n + j] += x * vt[c * n + j];
                }
            }
        }
        let err = stats::frobenius_diff(&rec, &a);
        let norm = stats::l2_norm(&a).max(1e-6);
        ensure(err / norm < 1e-3, format!("rel err {}", err / norm))
    });
}

#[test]
fn prop_mask_overlap_bounds_and_identity() {
    check("overlap in [0,1], self=1", |rng| {
        let n = gen_size(rng, 4, 200);
        let k = 1 + rng.below(n / 2 + 1);
        let a: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        let b: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        let o = mask_overlap(&a, &b);
        ensure((0.0..=1.0).contains(&o), "bounds")?;
        ensure_close(mask_overlap(&a, &a), 1.0, 1e-12, "self overlap")
    });
}

#[test]
fn prop_grid_dedups_and_ids_are_unique() {
    // axis values drawn WITH duplicates: the expansion must collapse
    // them (cell count = product of deduped axis sizes) and every cell
    // id must be unique
    fn uniq_count<T: Ord + Clone>(v: &[T]) -> usize {
        let mut s: Vec<T> = v.to_vec();
        s.sort();
        s.dedup();
        s.len()
    }
    check("grid dedup + unique ids", |rng| {
        let methods: Vec<String> =
            (0..1 + rng.below(5)).map(|_| format!("m{}", rng.below(3))).collect();
        let suites: Vec<String> =
            (0..1 + rng.below(3)).map(|_| format!("s{}", rng.below(2))).collect();
        let ranks: Vec<usize> = (0..1 + rng.below(4)).map(|_| 1 + rng.below(3)).collect();
        let seeds: Vec<u64> = (0..1 + rng.below(4)).map(|_| rng.below(3) as u64).collect();
        let cells = Grid::new(4)
            .with_axis(Axis::Method(methods.clone()))
            .with_axis(Axis::Suite(suites.clone()))
            .with_axis(Axis::Rank(ranks.clone()))
            .with_axis(Axis::Seed(seeds.clone()))
            .expand();
        let want =
            uniq_count(&methods) * uniq_count(&suites) * uniq_count(&ranks) * uniq_count(&seeds);
        ensure(
            cells.len() == want,
            format!("{} cells, want {want} after dedup", cells.len()),
        )?;
        let ids: std::collections::HashSet<String> = cells.iter().map(|c| c.id()).collect();
        ensure(ids.len() == cells.len(), "duplicate cell ids in a deduped grid")
    });
}

#[test]
fn prop_grid_expansion_is_axis_order_invariant() {
    // the same axes added in a random permutation order must expand to
    // the identical cell vector (content AND order) — the invariant the
    // golden file in rust/tests/grid.rs pins for one reference grid
    check("grid axis-order invariance", |rng| {
        let axes = vec![
            Axis::Preset(vec![format!("p{}", rng.below(3)), "toy".to_string()]),
            Axis::Method(vec!["lift".to_string(), format!("m{}", rng.below(3))]),
            Axis::Suite(vec![format!("s{}", rng.below(2))]),
            Axis::Rank(vec![1 + rng.below(4), 1 + rng.below(4)]),
            Axis::Interval(vec![1 + rng.below(5)]),
            Axis::Seed(vec![rng.below(4) as u64, rng.below(4) as u64]),
        ];
        let mut order: Vec<usize> = (0..axes.len()).collect();
        for i in (1..order.len()).rev() {
            let j = rng.below(i + 1);
            order.swap(i, j);
        }
        let canonical = axes
            .iter()
            .cloned()
            .fold(Grid::new(3), |g, a| g.with_axis(a))
            .expand();
        let permuted = order
            .iter()
            .map(|&i| axes[i].clone())
            .fold(Grid::new(3), |g, a| g.with_axis(a))
            .expand();
        ensure(
            canonical == permuted,
            format!("axis order {order:?} changed the expansion"),
        )
    });
}

#[test]
fn prop_any_spec_field_change_changes_the_id() {
    // cell identity covers EVERY spec field: mutating any one of them
    // (others held fixed) must produce a different id, so no changed
    // configuration can ever reuse a stale ledger entry
    check("cell id injective per field", |rng| {
        let base = CellSpec {
            preset: format!("p{}", rng.below(4)),
            method: format!("m{}", rng.below(4)),
            suite: format!("s{}", rng.below(4)),
            rank: rng.below(64),
            seed: rng.below(64) as u64,
            steps: 1 + rng.below(64),
            interval: 1 + rng.below(64),
            qscan: rng.below(2) == 1,
        };
        let id = base.id();
        let variants = vec![
            CellSpec { preset: format!("{}x", base.preset), ..base.clone() },
            CellSpec { method: format!("{}x", base.method), ..base.clone() },
            CellSpec { suite: format!("{}x", base.suite), ..base.clone() },
            CellSpec { rank: base.rank + 1, ..base.clone() },
            CellSpec { seed: base.seed + 1, ..base.clone() },
            CellSpec { steps: base.steps + 1, ..base.clone() },
            CellSpec { interval: base.interval + 1, ..base.clone() },
            CellSpec { qscan: !base.qscan, ..base.clone() },
        ];
        for v in variants {
            ensure(
                v.id() != id,
                format!("changing {v:?} kept the id {id}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_qscan_mask_overlap_meets_contract() {
    // the quantized scan's documented tolerance contract
    // (eigh::LIFT_QSCAN_TOL): across shapes, ranks, and spectral decays
    // the int8 scan's top-k selection must overlap the f64 scan's by at
    // least the contract floor. Override the floor with the env var
    // LIFT_QSCAN_TOL to probe the actual margin.
    let tol = std::env::var("LIFT_QSCAN_TOL")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(eigh::LIFT_QSCAN_TOL);
    check("qscan selection contract", |rng| {
        let m = 40 + rng.below(33);
        let n = 40 + rng.below(33);
        let r = 2 + rng.below(4);
        // low-rank signal with a random spectral decay + small additive
        // noise — the regime the paper's rank-reduce scan runs in
        let qa = random_orthonormal(rng, m, r);
        let qb = random_orthonormal(rng, n, r);
        let decay = 0.4 + 0.05 * rng.below(10) as f64;
        let mut a = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                let mut sv = 1.0f64;
                for c in 0..r {
                    acc += sv * qa[i * r + c] as f64 * qb[j * r + c] as f64;
                    sv *= decay;
                }
                a[i * n + j] = acc as f32 + rng.normal() * 0.02;
            }
        }
        let mut s64 = eigh::EighScratch::new();
        let (wr64, _) = eigh::lowrank_approx_warm(&a, m, n, r, None, &mut s64);
        let mut sq = eigh::EighScratch::new();
        sq.set_qscan(true);
        let (wrq, _) = eigh::lowrank_approx_warm(&a, m, n, r, None, &mut sq);
        let k = budget_for(m, n, 2);
        let ov = mask_overlap(&topk_indices(&wr64, k), &topk_indices(&wrq, k));
        ensure(
            ov >= tol,
            format!("({m},{n}) r={r} decay={decay:.2}: qscan overlap {ov:.4} < {tol}"),
        )
    });
}

#[test]
fn prop_histogram_conserves_mass() {
    check("histogram mass", |rng| {
        let n = gen_size(rng, 1, 500);
        let xs = rng.normal_vec(n, 2.0);
        let h = stats::histogram(&xs, -1.0, 1.0, 1 + rng.below(30));
        ensure(h.iter().sum::<usize>() == n, "mass lost")
    });
}

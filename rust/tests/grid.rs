//! Golden-ledger & eval-oracle lock-in for the generalized scenario
//! matrix (ISSUE 5):
//!
//! * cell ids are a pure function of cell field values — expanding the
//!   same grid with axes added in ANY order yields the identical id
//!   vector, pinned byte-for-byte by `golden/grid_ids.txt`;
//! * ledger v2 policy round-trips: a v1 (pre-versioning) outcome makes
//!   the campaign REFUSE until explicitly migrated — migration preserves
//!   every v1 field, carries orphaned checkpoint dirs, and the migrated
//!   cell is skipped (never recomputed); a future-version ledger aborts;
//!   corrupt files recompute loudly; `summary.txt` renders `-` instead
//!   of panicking on empty/failed/corrupt campaigns;
//! * resume-mid-axis determinism: a campaign over the NEW axes
//!   (interval × seed), interrupted both mid-cell (crash with a snapshot
//!   on disk) and mid-axis (some cells finished, some untouched), then
//!   resumed — per-cell outcomes bit-identical to an uninterrupted
//!   campaign, at 1 worker and at N workers, and identical across
//!   worker counts;
//! * the artifact-free retention proxy reproduces the paper's
//!   qualitative ordering: sparse methods retain, Full FT forgets.
//!
//! Everything here runs without AOT artifacts (toy cells drive the real
//! trainer loop via `exp::matrix::synth_step`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use lift::ckpt;
use lift::exp::grid::{Axis, Grid};
use lift::exp::matrix::{self, CellSpec};
use lift::tensor::Tensor;
use lift::train::{train_with, TrainCfg};
use lift::util::rng::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lift_grid_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---- golden cell-id stability ------------------------------------------

fn golden_axes() -> Vec<Axis> {
    vec![
        Axis::Preset(vec!["toy".into(), "tiny".into()]),
        Axis::Method(vec!["lift".into(), "full".into(), "weight_mag".into()]),
        Axis::Suite(vec!["arith".into(), "nlu".into()]),
        Axis::Rank(vec![2, 4]),
        Axis::Interval(vec![2, 4]),
        Axis::Seed(vec![1, 2]),
    ]
}

/// The expansion of the reference grid is pinned byte-for-byte: content
/// AND order. If this golden diff ever fires, either cell identity or
/// the canonical axis order changed — both invalidate every on-disk
/// ledger, so the change must ship a migration, not a silent rename.
#[test]
fn golden_cell_ids_are_stable_across_axis_order_permutations() {
    let golden: Vec<String> = include_str!("golden/grid_ids.txt")
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(golden.len(), 96, "golden file shape changed");
    let perms: [[usize; 6]; 4] = [
        [0, 1, 2, 3, 4, 5],
        [5, 4, 3, 2, 1, 0],
        [2, 0, 5, 1, 4, 3],
        [3, 5, 0, 4, 2, 1],
    ];
    for perm in perms {
        let axes = golden_axes();
        let mut grid = Grid::new(6);
        for &i in &perm {
            grid = grid.with_axis(axes[i].clone());
        }
        let ids: Vec<String> = grid.expand().iter().map(|c| c.id()).collect();
        assert_eq!(ids, golden, "axis insertion order {perm:?} moved cell ids");
    }
    // the qscan axis (ISSUE 10) defaults off, and an EXPLICIT
    // qscan=false axis is the same cell set: every golden id must stay
    // byte-identical, so pre-qscan ledgers keep resolving
    let mut grid = Grid::new(6);
    for ax in golden_axes() {
        grid = grid.with_axis(ax);
    }
    let grid = grid.with_axis(Axis::Qscan(vec![false]));
    let ids: Vec<String> = grid.expand().iter().map(|c| c.id()).collect();
    assert_eq!(ids, golden, "explicit qscan=false moved cell ids");
}

// ---- ledger v1 -> v2 ----------------------------------------------------

#[test]
fn v1_ledger_refuses_then_migrates_without_recompute() {
    let dir = tmpdir("v1_migrate");
    let cells = matrix::expand_grid(
        "toy",
        &["weight_mag".to_string(), "random".to_string()],
        &[],
        &[2],
        &[1],
        4,
        2,
    );
    assert_eq!(cells.len(), 2);
    // a finished v1 outcome for cell 0 under its PRE-SUITE id
    let v1_json = "{\"label\":\"WMAG\",\"accs\":[1.5,2.5],\"avg\":2,\"tail_loss\":0.5,\
                   \"trainable\":3,\"opt_bytes\":24,\"seconds\":0.25,\"steps\":4}";
    std::fs::write(matrix::outcome_path(&dir, &cells[0].v1_id()), v1_json).unwrap();
    // and an orphaned v1 checkpoint dir for cell 1 (interrupted v1 cell)
    let old_ckpt = matrix::cell_ckpt_dir(&dir, &cells[1].v1_id());
    std::fs::create_dir_all(&old_ckpt).unwrap();
    std::fs::write(old_ckpt.join("marker"), b"x").unwrap();
    // the campaign refuses: finished v1 work is never silently recomputed
    let err = matrix::run_matrix(&dir, &cells, 2, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1))
        .unwrap_err()
        .to_string();
    assert!(err.contains("v1"), "{err}");
    assert!(err.contains("--migrate-v1"), "{err}");
    // the v1 file survived the refusal byte-identically
    assert_eq!(
        std::fs::read_to_string(matrix::outcome_path(&dir, &cells[0].v1_id())).unwrap(),
        v1_json
    );
    // migrate: the outcome moves to the v2 id with every v1 field kept
    let migrated = matrix::migrate_v1(&dir, &cells).unwrap();
    assert_eq!(migrated, vec![cells[0].id()]);
    let got = matrix::read_outcome(&dir, &cells[0].id()).unwrap();
    assert_eq!(got.label, "WMAG");
    assert_eq!(got.accs, vec![1.5, 2.5]);
    assert_eq!(got.avg, 2.0);
    assert_eq!(got.tail_loss, 0.5);
    assert_eq!(got.trainable, 3);
    assert_eq!(got.opt_bytes, 24);
    assert_eq!(got.seconds, 0.25);
    assert_eq!(got.steps, 4);
    // retention columns start empty on migrated entries (render '-')
    assert_eq!(got.target, None);
    assert_eq!(got.source, None);
    assert_eq!(got.retention, None);
    assert!(
        !matrix::outcome_path(&dir, &cells[0].v1_id()).exists(),
        "v1 file must be consumed by migration"
    );
    // the orphaned v1 ckpt dir was renamed onto the v2 id
    assert!(matrix::cell_ckpt_dir(&dir, &cells[1].id()).join("marker").exists());
    assert!(!old_ckpt.exists());
    // rerun: the migrated cell is SKIPPED (zero recompute), only the
    // never-finished cell executes
    let count = AtomicUsize::new(0);
    let report = matrix::run_matrix(&dir, &cells, 2, |s| {
        count.fetch_add(1, Ordering::SeqCst);
        matrix::run_toy_cell(s, &dir, 0, 0, 1)
    })
    .unwrap();
    assert_eq!(report.skipped, vec![cells[0].id()]);
    assert_eq!(report.ran, vec![cells[1].id()]);
    assert_eq!(count.load(Ordering::SeqCst), 1, "migrated cell must not recompute");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn migration_roundtrips_a_v2_rewrite_of_the_v1_fields() {
    // v1 json -> migrate -> v2 file -> reparse: the v2 file carries the
    // version marker and reparses equal to the migrated outcome
    let dir = tmpdir("v1_roundtrip");
    let cells = matrix::expand_grid("toy", &["lift".to_string()], &[], &[4], &[7], 9, 3);
    let v1_json = "{\"label\":\"LIFT\",\"accs\":[10,20,30],\"avg\":20,\"tail_loss\":0.125,\
                   \"trainable\":640,\"opt_bytes\":7680,\"seconds\":1.5,\"steps\":9}";
    std::fs::write(matrix::outcome_path(&dir, &cells[0].v1_id()), v1_json).unwrap();
    matrix::migrate_v1(&dir, &cells).unwrap();
    let raw = std::fs::read_to_string(matrix::outcome_path(&dir, &cells[0].id())).unwrap();
    assert!(raw.contains("\"v\":2"), "{raw}");
    let a = matrix::read_outcome(&dir, &cells[0].id()).unwrap();
    assert_eq!(a.accs, vec![10.0, 20.0, 30.0]);
    assert_eq!(a.avg, 20.0);
    // a second migration is a no-op (nothing left to move)
    assert!(matrix::migrate_v1(&dir, &cells).unwrap().is_empty());
    assert_eq!(matrix::read_outcome(&dir, &cells[0].id()).unwrap(), a);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn migration_refuses_an_ambiguous_multi_suite_grid() {
    // a v1 id records no suite: migrating it onto a grid that sweeps
    // several suites would have to guess which suite the v1 campaign
    // trained — that must refuse, never mislabel finished work
    let dir = tmpdir("v1_ambiguous");
    let cells = Grid::new(4)
        .with_axis(Axis::Preset(vec!["toy".into()]))
        .with_axis(Axis::Method(vec!["lift".into()]))
        .with_axis(Axis::Suite(vec!["arith".into(), "nlu".into()]))
        .with_axis(Axis::Rank(vec![2]))
        .with_axis(Axis::Interval(vec![2]))
        .with_axis(Axis::Seed(vec![1]))
        .expand();
    assert_eq!(cells.len(), 2);
    assert_eq!(cells[0].v1_id(), cells[1].v1_id(), "same v1 id across suites");
    let v1_json = "{\"label\":\"LIFT\",\"accs\":[],\"avg\":0,\"tail_loss\":0.5,\
                   \"trainable\":3,\"opt_bytes\":24,\"seconds\":0.25,\"steps\":4}";
    std::fs::write(matrix::outcome_path(&dir, &cells[0].v1_id()), v1_json).unwrap();
    let err = matrix::migrate_v1(&dir, &cells).unwrap_err().to_string();
    assert!(err.contains("arith, nlu"), "{err}");
    // nothing moved: the v1 file is intact and no v2 outcome appeared
    assert_eq!(
        std::fs::read_to_string(matrix::outcome_path(&dir, &cells[0].v1_id())).unwrap(),
        v1_json
    );
    assert!(matrix::read_outcome(&dir, &cells[0].id()).is_none());
    assert!(matrix::read_outcome(&dir, &cells[1].id()).is_none());
    // narrowing to the single original suite migrates cleanly
    let arith: Vec<CellSpec> = cells.iter().filter(|c| c.suite == "arith").cloned().collect();
    let migrated = matrix::migrate_v1(&dir, &arith).unwrap();
    assert_eq!(migrated, vec![arith[0].id()]);
    assert!(matrix::read_outcome(&dir, &arith[0].id()).is_some());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn future_ledger_version_aborts_instead_of_recomputing() {
    let dir = tmpdir("future_ledger");
    let cells = matrix::expand_grid("toy", &["weight_mag".to_string()], &[], &[2], &[1], 4, 2);
    let future = "{\"v\":3,\"label\":\"FUTURE\"}";
    std::fs::write(matrix::outcome_path(&dir, &cells[0].id()), future).unwrap();
    let err = matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1))
        .unwrap_err()
        .to_string();
    assert!(err.contains("newer"), "{err}");
    // the future file is untouched by the refusal
    assert_eq!(
        std::fs::read_to_string(matrix::outcome_path(&dir, &cells[0].id())).unwrap(),
        future
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- summary regression -------------------------------------------------

#[test]
fn summary_renders_dashes_for_empty_failed_and_corrupt_ledgers() {
    let dir = tmpdir("summary_dashes");
    let cells = matrix::expand_grid(
        "toy",
        &["weight_mag".to_string(), "random".to_string()],
        &[],
        &[2, 4],
        &[1],
        4,
        2,
    );
    assert_eq!(cells.len(), 4);
    // zero finished cells: header + '-' everywhere, rows intact, no panic
    let t0 = matrix::summary_table(&dir, &cells);
    assert!(t0.contains("0/4 cells finished"), "{t0}");
    assert!(t0.contains("r=2 tgt") && t0.contains("r=4 ret"), "{t0}");
    for m in ["weight_mag", "random"] {
        assert!(t0.contains(m), "method row dropped: {t0}");
    }
    assert!(
        t0.matches('-').count() >= 8,
        "2 methods x 2 ranks x (tgt, ret) must all render '-': {t0}"
    );
    // all-failed campaign: no outcomes land -> same all-dash shape
    let report = matrix::run_matrix(&dir, &cells, 2, |_s| -> anyhow::Result<matrix::CellOutcome> {
        anyhow::bail!("synthetic cell failure")
    })
    .unwrap();
    assert_eq!(report.failed.len(), 4);
    let t1 = matrix::summary_table(&dir, &cells);
    assert!(t1.contains("0/4 cells finished"), "{t1}");
    // run for real, then corrupt one outcome: that cell reverts to '-'
    let r2 = matrix::run_matrix(&dir, &cells, 2, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1))
        .unwrap();
    assert_eq!(r2.ran.len(), 4, "{:?}", r2.failed);
    let t2 = matrix::summary_table(&dir, &cells);
    assert!(t2.contains("4/4 cells finished"), "{t2}");
    std::fs::write(matrix::outcome_path(&dir, &cells[0].id()), "{torn-write").unwrap();
    let t3 = matrix::summary_table(&dir, &cells);
    assert!(t3.contains("3/4 cells finished"), "{t3}");
    assert!(t3.contains('-'), "{t3}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- resume-mid-axis determinism ---------------------------------------

/// The acceptance scenario: a grid spanning the new axes (interval ×
/// seed on the toy preset), one campaign straight, one interrupted both
/// mid-cell (crash leaving a snapshot) and mid-axis (some cells done,
/// the rest untouched), then resumed. Per-cell outcomes must be
/// bit-identical — within each worker count AND across worker counts.
#[test]
fn interrupted_campaign_resumes_bit_identically_on_the_new_axes() {
    let cells = Grid::new(4)
        .with_axis(Axis::Preset(vec!["toy".into()]))
        .with_axis(Axis::Method(vec!["lift".into(), "full".into()]))
        .with_axis(Axis::Interval(vec![2, 3]))
        .with_axis(Axis::Seed(vec![1, 2]))
        .expand();
    assert_eq!(cells.len(), 8);
    let mut reference: Option<Vec<(String, u32, Option<f64>)>> = None;
    for workers in [1usize, 4] {
        let dir_a = tmpdir(&format!("straight_{workers}"));
        let ra = matrix::run_matrix(&dir_a, &cells, workers, |s| {
            matrix::run_toy_cell(s, &dir_a, 2, 0, 1)
        })
        .unwrap();
        assert_eq!(ra.ran.len(), 8, "failed: {:?}", ra.failed);
        let dir_b = tmpdir(&format!("resumed_{workers}"));
        // crash one cell mid-train: snapshot at step 2 of 4 lands, then
        // the gradient source dies (the ckpt.rs crash pattern)
        let victim = &cells[3];
        {
            let ckpt_dir = matrix::cell_ckpt_dir(&dir_b, &victim.id());
            let mut ctx = matrix::toy_ctx(1, 0xC311 ^ victim.seed).unwrap();
            let mut params = matrix::toy_params(0x1717 ^ victim.seed);
            let mut method = victim.method_with_lra(victim.rank.clamp(1, 8)).unwrap();
            let cfg = TrainCfg {
                steps: victim.steps,
                lr: 1e-3,
                warmup_frac: 0.03,
                log_every: 0,
                seed: victim.seed,
                ckpt_every: 2,
                ckpt_dir: Some(ckpt_dir.clone()),
                ckpt_keep: 0,
            };
            let mut served = 0usize;
            let mut dying = |params: &[Tensor], rng: &mut Rng| {
                if served == 2 {
                    anyhow::bail!("simulated crash");
                }
                served += 1;
                matrix::synth_step(params, rng)
            };
            train_with(&mut dying, &mut *method, &mut ctx, &mut params, &cfg, None)
                .unwrap_err();
            assert!(ckpt::latest_snapshot(&ckpt_dir).unwrap().is_some());
        }
        // pre-finish two other cells so the rerun starts mid-axis
        let pre: Vec<CellSpec> = vec![cells[0].clone(), cells[6].clone()];
        let rp = matrix::run_matrix(&dir_b, &pre, workers, |s| {
            matrix::run_toy_cell(s, &dir_b, 2, 0, 1)
        })
        .unwrap();
        assert_eq!(rp.ran.len(), 2);
        // resume the whole campaign: done cells skip, the crashed cell
        // picks up its snapshot, the rest run fresh
        let rb = matrix::run_matrix(&dir_b, &cells, workers, |s| {
            matrix::run_toy_cell(s, &dir_b, 2, 0, 1)
        })
        .unwrap();
        assert_eq!(rb.skipped.len(), 2);
        assert_eq!(rb.ran.len(), 6);
        // every outcome bit-identical to the straight campaign (seconds
        // is wall time, the one legitimately nondeterministic field)
        for c in &cells {
            let a = matrix::read_outcome(&dir_a, &c.id()).unwrap();
            let b = matrix::read_outcome(&dir_b, &c.id()).unwrap();
            assert_eq!(a.tail_loss.to_bits(), b.tail_loss.to_bits(), "{}", c.id());
            assert_eq!(a.retention, b.retention, "{}", c.id());
            assert_eq!(a.target, b.target, "{}", c.id());
            assert_eq!(a.source, b.source, "{}", c.id());
            assert_eq!(a.label, b.label, "{}", c.id());
            assert_eq!(a.accs, b.accs, "{}", c.id());
            assert_eq!(a.trainable, b.trainable, "{}", c.id());
            assert_eq!(a.opt_bytes, b.opt_bytes, "{}", c.id());
            assert_eq!(a.steps, b.steps, "{}", c.id());
        }
        // and across worker counts: 1w ≡ Nw per cell
        let snap: Vec<(String, u32, Option<f64>)> = cells
            .iter()
            .map(|c| {
                let o = matrix::read_outcome(&dir_a, &c.id()).unwrap();
                (c.id(), o.tail_loss.to_bits(), o.retention)
            })
            .collect();
        match &reference {
            None => reference = Some(snap),
            Some(r) => assert_eq!(r, &snap, "outcomes differ across worker counts"),
        }
        std::fs::remove_dir_all(&dir_a).unwrap();
        std::fs::remove_dir_all(&dir_b).unwrap();
    }
}

// ---- retention ordering -------------------------------------------------

#[test]
fn toy_retention_separates_sparse_from_full_ft() {
    let dir = tmpdir("toy_retention");
    let cells = matrix::expand_grid(
        "toy",
        &["full".to_string(), "weight_mag".to_string()],
        &[],
        &[2],
        &[1],
        4,
        2,
    );
    let r = matrix::run_matrix(&dir, &cells, 2, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1))
        .unwrap();
    assert_eq!(r.ran.len(), 2, "{:?}", r.failed);
    let by_method = |m: &str| {
        let c = cells.iter().find(|c| c.method == m).unwrap();
        matrix::read_outcome(&dir, &c.id()).unwrap()
    };
    let full = by_method("full");
    let sparse = by_method("weight_mag");
    let rf = full.retention.unwrap();
    let rs = sparse.retention.unwrap();
    assert!((0.0..=1.0).contains(&rf), "full retention out of range: {rf}");
    assert!((0.0..=1.0).contains(&rs), "sparse retention out of range: {rs}");
    // the paper's qualitative ordering in the toy world: Full FT moves
    // (almost) every weight; the budgeted sparse method leaves the
    // non-principal ones bit-identical
    assert!(rs > rf + 0.2, "sparse {rs} should retain far more than full {rf}");
    assert!(rs > 0.5, "sparse method should keep most weights: {rs}");
    // toy cells also carry the target tail-perplexity metric
    assert!(sparse.target.unwrap().perplexity.unwrap() > 0.0);
    // and the summary surfaces the retention columns
    let (_, table) = matrix::write_summary(&dir, &cells).unwrap();
    assert!(table.contains("ret"), "{table}");
    std::fs::remove_dir_all(&dir).unwrap();
}

//! Serve-layer acceptance suite (ISSUE 8): overlay-apply ≡ full tenant
//! materialization bitwise, LRU evict/readmit determinism at 1 and N
//! workers, hot-swap never serving a torn delta mid-request-stream, and
//! loud spec-digest refusal.

use std::path::PathBuf;

use lift::exp::matrix::{toy_params, toy_preset};
use lift::serve::{
    base_digest, forward_one, synth_delta, BaseModel, DeltaStore, ForwardPlan, OverlayModel,
    Request, Server, TenantDelta, TenantView,
};
use lift::tensor::Tensor;
use lift::util::rng::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lift_serve_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn toy_view_bytes(base: &[Tensor]) -> usize {
    let dg = base_digest(base);
    TenantView::materialize(base, &synth_delta(base, "probe", dg, 2, 1))
        .unwrap()
        .bytes()
}

/// Overlay-apply must equal scattering the delta into a dense base copy,
/// bit for bit, for every tenant/seed probed — the core serving claim.
#[test]
fn overlay_apply_equals_full_materialization_bitwise() {
    let base = toy_params(11);
    let plan = ForwardPlan::from_preset(&toy_preset()).unwrap();
    let dg = base_digest(&base);
    for tseed in [1u64, 2, 3, 99] {
        let delta = synth_delta(&base, &format!("t{tseed}"), dg, 2, tseed);
        let view = TenantView::materialize(&base, &delta).unwrap();
        let dense = TenantView::full_materialize(&base, &delta).unwrap();
        for probe in [0u64, 5, 17, 31] {
            let over = forward_one(&OverlayModel { base: &base, view: &view }, &plan, probe);
            let full = forward_one(&BaseModel { base: &dense }, &plan, probe);
            assert!(
                over.iter().zip(&full).all(|(x, y)| x.to_bits() == y.to_bits()),
                "tenant seed {tseed}, probe {probe}: overlay != dense"
            );
            let plain = forward_one(&BaseModel { base: &base }, &plan, probe);
            assert_ne!(over, plain, "delta changed nothing (tseed {tseed}, probe {probe})");
        }
    }
}

/// The same request stream through a churning tiny-budget LRU, a
/// hold-everything budget, and 1 vs N workers must produce bit-identical
/// outputs — caching and parallelism are invisible to results.
#[test]
fn lru_evict_readmit_is_deterministic_at_any_worker_count() {
    let base = toy_params(12);
    let preset = toy_preset();
    let dg = base_digest(&base);
    let dir = tmpdir("lru_det");
    let n_tenants = 6usize;
    {
        let store = DeltaStore::open(&dir, dg).unwrap();
        for i in 0..n_tenants {
            store.register(&synth_delta(&base, &format!("t{i}"), dg, 2, 100 + i as u64)).unwrap();
        }
    }
    // a stream that revisits evicted tenants (readmit on miss)
    let mut rng = Rng::new(0xfeed);
    let stream: Vec<Request> = (0..60)
        .map(|_| Request { tenant: format!("t{}", rng.below(n_tenants)), seed: rng.next_u64() })
        .collect();
    let one_view = toy_view_bytes(&base);
    let run = |budget: usize, workers: usize| -> (Vec<Vec<f32>>, u64) {
        let mut server = Server::new(&base, &preset, &dir, budget, workers).unwrap();
        let mut outs = Vec::new();
        for chunk in stream.chunks(8) {
            outs.extend(server.handle_batch(chunk).unwrap());
        }
        (outs, server.lru().stats.evictions)
    };
    let (tiny_1w, ev_tiny_1w) = run(2 * one_view + 2, 1);
    let (tiny_4w, ev_tiny_4w) = run(2 * one_view + 2, 4);
    let (big_1w, ev_big) = run(usize::MAX, 1);
    let (big_4w, _) = run(usize::MAX, 4);
    assert!(ev_tiny_1w > 0, "tiny budget never evicted — test fixture too roomy");
    assert_eq!(ev_tiny_1w, ev_tiny_4w, "eviction count must not depend on workers");
    assert_eq!(ev_big, 0, "hold-everything budget must not evict");
    let bits = |outs: &[Vec<f32>]| -> Vec<Vec<u32>> {
        outs.iter().map(|o| o.iter().map(|x| x.to_bits()).collect()).collect()
    };
    assert_eq!(bits(&tiny_1w), bits(&tiny_4w), "tiny budget: 1w != 4w");
    assert_eq!(bits(&tiny_1w), bits(&big_1w), "LRU churn changed outputs");
    assert_eq!(bits(&big_1w), bits(&big_4w), "big budget: 1w != 4w");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Hot-swap mid-stream: a view Arc held across the swap (an in-flight
/// request) keeps reading the complete OLD version, fresh requests read
/// exactly the NEW version (bitwise equal to a fresh server), and
/// unrelated tenants stay resident.
#[test]
fn hot_swap_never_serves_a_torn_delta() {
    let base = toy_params(13);
    let preset = toy_preset();
    let plan = ForwardPlan::from_preset(&preset).unwrap();
    let dg = base_digest(&base);
    let dir = tmpdir("hot_swap");
    let mut server = Server::new(&base, &preset, &dir, usize::MAX, 2).unwrap();
    for i in 0..4 {
        server
            .store()
            .register(&synth_delta(&base, &format!("t{i}"), dg, 2, 200 + i as u64))
            .unwrap();
    }
    let warm: Vec<Request> =
        (0..4).map(|i| Request { tenant: format!("t{i}"), seed: i as u64 }).collect();
    server.handle_batch(&warm).unwrap();
    assert_eq!(server.lru().resident(), 4);

    // "in-flight request": materialize t0's current (v1) view directly
    let v1_delta = server.store().load("t0").unwrap();
    let held = TenantView::materialize(&base, &v1_delta).unwrap();
    let probe = 0x5eedu64;
    let v1_out = forward_one(&OverlayModel { base: &base, view: &held }, &plan, probe);

    let v2_delta = synth_delta(&base, "t0", dg, 2, 999);
    server.hot_swap(&v2_delta).unwrap();

    // unrelated tenants untouched
    assert_eq!(server.lru().resident_tenants(), vec!["t0", "t1", "t2", "t3"]);
    assert_eq!(server.lru().stats.evictions, 0);
    // the held (old) view still answers exactly v1 — no tearing
    let held_out = forward_one(&OverlayModel { base: &base, view: &held }, &plan, probe);
    assert_eq!(
        v1_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        held_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
    // a fresh request sees exactly v2: bitwise equal to a fresh server
    // over the same store, and different from v1
    let req = Request { tenant: "t0".into(), seed: probe };
    let served = server.handle_batch(std::slice::from_ref(&req)).unwrap().remove(0);
    assert_ne!(served, v1_out, "swap did not change t0's output");
    let mut fresh = Server::new(&base, &preset, &dir, usize::MAX, 1).unwrap();
    let fresh_out = fresh.handle_batch(std::slice::from_ref(&req)).unwrap().remove(0);
    assert_eq!(
        served.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        fresh_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "swapped view is not the pure v2 materialization"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A delta trained against a different base is refused loudly at load,
/// at register, and at raw parse — never overlaid quietly.
#[test]
fn spec_digest_mismatch_is_refused() {
    let base = toy_params(14);
    let other = toy_params(15);
    let dg = base_digest(&base);
    let dg_other = base_digest(&other);
    assert_ne!(dg, dg_other);
    let dir = tmpdir("digest");
    // registered against `other`, loaded by a store pinned to `base`
    {
        let store_other = DeltaStore::open(&dir, dg_other).unwrap();
        store_other.register(&synth_delta(&other, "alice", dg_other, 2, 7)).unwrap();
    }
    let store = DeltaStore::open(&dir, dg).unwrap();
    let err = store.load("alice").unwrap_err().to_string();
    assert!(err.contains("refusing to overlay"), "load error was: {err}");
    // raw parse path says both digests
    let bytes = synth_delta(&other, "alice", dg_other, 2, 7).to_bytes();
    let err = TenantDelta::from_bytes(&bytes, dg).unwrap_err().to_string();
    assert!(err.contains(&format!("{dg_other:016x}")), "missing delta digest: {err}");
    assert!(err.contains(&format!("{dg:016x}")), "missing server digest: {err}");
    // register on the mismatched store is refused before touching disk
    let err = store.register(&synth_delta(&other, "bob", dg_other, 2, 8)).unwrap_err().to_string();
    assert!(err.contains("pinned"), "register error was: {err}");
    assert!(store.load("bob").is_err(), "refused register must not leave a file");
    // the server surfaces the refusal on a request for the bad tenant
    let mut server = Server::new(&base, &toy_preset(), &dir, usize::MAX, 1).unwrap();
    let req = Request { tenant: "alice".into(), seed: 1 };
    assert!(server.handle_batch(std::slice::from_ref(&req)).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A register that crashed between temp-write and rename leaves an
/// orphaned `.tmp` in the store dir. The store must keep working: list()
/// skips the orphan (and any other non-delta droppings) with a warning
/// instead of erroring, committed tenants still load, and a fresh
/// register over the same tenant consumes the orphan (satellite 2).
#[test]
fn crashed_register_leaves_the_store_usable() {
    let base = toy_params(17);
    let preset = toy_preset();
    let dg = base_digest(&base);
    let dir = tmpdir("crashed_register");
    {
        let store = DeltaStore::open(&dir, dg).unwrap();
        store.register(&synth_delta(&base, "alice", dg, 2, 1)).unwrap();
        store.register(&synth_delta(&base, "bob", dg, 2, 2)).unwrap();
    }
    // simulate the debris a crash mid-register leaves behind: a torn temp
    // for a brand-new tenant, a stray non-delta file, and a subdirectory
    std::fs::write(dir.join("carol.tmp"), b"torn half-written delta").unwrap();
    std::fs::write(dir.join("notes.txt"), b"not a delta").unwrap();
    std::fs::create_dir_all(dir.join("subdir")).unwrap();

    let store = DeltaStore::open(&dir, dg).unwrap();
    assert_eq!(
        store.list().unwrap(),
        vec!["alice", "bob"],
        "droppings must be skipped, committed tenants listed"
    );
    // committed deltas are untouched and load cleanly
    assert_eq!(store.load("alice").unwrap().tenant, "alice");
    assert_eq!(store.load("bob").unwrap().tenant, "bob");
    // the crashed tenant never committed: loading it is a plain miss
    assert!(store.load("carol").is_err(), "a torn temp must not serve");
    // a retried register lands and replaces the orphan as a side effect
    store.register(&synth_delta(&base, "carol", dg, 2, 3)).unwrap();
    assert!(!dir.join("carol.tmp").exists(), "retried register consumes the orphan");
    assert_eq!(store.list().unwrap(), vec!["alice", "bob", "carol"]);
    // a server over the littered dir comes up and serves normally
    let mut server = Server::new(&base, &preset, &dir, usize::MAX, 1).unwrap();
    let reqs: Vec<Request> = ["alice", "bob", "carol"]
        .iter()
        .map(|t| Request { tenant: (*t).into(), seed: 5 })
        .collect();
    let outs = server.handle_batch(&reqs).unwrap();
    assert_eq!(outs.len(), 3);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Register-as-update: re-registering a tenant replaces its delta
/// atomically, and delete_tenant removes both file and resident view.
#[test]
fn register_update_delete_lifecycle() {
    let base = toy_params(16);
    let preset = toy_preset();
    let dg = base_digest(&base);
    let dir = tmpdir("lifecycle");
    let mut server = Server::new(&base, &preset, &dir, usize::MAX, 1).unwrap();
    server.store().register(&synth_delta(&base, "a", dg, 2, 1)).unwrap();
    let req = Request { tenant: "a".into(), seed: 3 };
    let out1 = server.handle_batch(std::slice::from_ref(&req)).unwrap().remove(0);
    // update through hot_swap (store write + resident view swap)
    server.hot_swap(&synth_delta(&base, "a", dg, 2, 2)).unwrap();
    let out2 = server.handle_batch(std::slice::from_ref(&req)).unwrap().remove(0);
    assert_ne!(out1, out2);
    assert_eq!(server.store().list().unwrap(), vec!["a"]);
    assert!(server.delete_tenant("a").unwrap());
    assert!(!server.delete_tenant("a").unwrap());
    assert_eq!(server.lru().resident(), 0);
    assert!(server.handle_batch(std::slice::from_ref(&req)).is_err(), "deleted tenant still serves");
    let _ = std::fs::remove_dir_all(&dir);
}

//! Cross-layer integration tests. These require `make artifacts` to have
//! run (they load the compiled HLO artifacts) and exercise the exact code
//! paths the coordinator uses in production.
//!
//! QUARANTINE NOTE: when the artifacts directory is absent (no jax to run
//! `make artifacts`, or a build that links the host-interpreter xla stub,
//! which cannot execute AOT HLO), every test here skips itself with an
//! explanatory line instead of failing. This keeps tier-1
//! (`cargo build --release && cargo test -q`) green in artifact-less
//! environments while preserving full coverage wherever artifacts exist.

use lift::data::tasks::{TaskMixSource, TaskSet, TaskFamily};
use lift::methods::{make_method, Method, Scope};
use lift::model;
use lift::optim::{AdamCfg, KernelAdam, SparseAdam};
use lift::runtime::model_exec::{Batch, ModelExec};
use lift::runtime::{ArtifactStatus, Linalg, Manifest, Runtime};
use lift::tensor::Tensor;
use lift::train::{pretrain, train, TrainCfg};
use lift::util::json::Json;
use lift::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    // tests run from the package root; skip-vs-fail policy lives in
    // Runtime::artifact_status (broken artifacts are a failure, not a skip)
    match Runtime::artifact_status() {
        Ok(ArtifactStatus::Ready(rt)) => Some(rt),
        Ok(ArtifactStatus::StubOnly) => {
            eprintln!(
                "SKIP (artifacts present but this build links the host-interpreter \
                 xla stub, which cannot run AOT HLO; link the native xla crate)"
            );
            None
        }
        Ok(ArtifactStatus::Missing(e)) => {
            // the CI jax job sets this after `make artifacts`: absence is
            // then a failure, never a silent skip
            if std::env::var("LIFT_EXPECT_ARTIFACTS").is_ok() {
                panic!("LIFT_EXPECT_ARTIFACTS is set but artifacts are missing: {e:#}");
            }
            eprintln!("SKIP (artifacts unavailable — run `make artifacts`): {e}");
            None
        }
        Err(e) => panic!("{e:#}"),
    }
}

#[test]
fn artifact_manifest_is_complete_when_present() {
    // Validates what `make artifacts` produced — file-level, so it runs
    // un-skipped even under the host-interpreter xla stub (which can't
    // *execute* AOT HLO but can absolutely check the contract of the
    // artifacts dir). The CI jax job relies on this running.
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        if std::env::var("LIFT_EXPECT_ARTIFACTS").is_ok() {
            panic!("LIFT_EXPECT_ARTIFACTS is set but {dir:?} has no manifest.json");
        }
        eprintln!("SKIP (artifacts unavailable — run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let check = |what: &str, file: &str| {
        let p = dir.join(file);
        let len = std::fs::metadata(&p)
            .unwrap_or_else(|e| panic!("{what} artifact missing at {p:?}: {e}"))
            .len();
        assert!(len > 0, "{what} artifact is empty: {p:?}");
    };
    assert!(!manifest.kernels.is_empty(), "manifest lists no kernels");
    for (name, file) in &manifest.kernels {
        check(&format!("kernel {name}"), file);
    }
    assert!(!manifest.presets.is_empty(), "manifest lists no presets");
    for (pname, preset) in &manifest.presets {
        assert!(
            !preset.executables.is_empty(),
            "preset {pname} lists no executables"
        );
        for (ename, file) in &preset.executables {
            check(&format!("preset {pname} executable {ename}"), file);
        }
    }
    // fixtures back the cross-language numeric contract
    let fx = dir.join("fixtures.json");
    let text = std::fs::read_to_string(&fx)
        .unwrap_or_else(|e| panic!("fixtures.json missing at {fx:?}: {e}"));
    Json::parse(&text).expect("fixtures.json does not parse");
}

/// Mirror of python/compile/fixtures.py deterministic_params.
fn fixture_params(exec: &ModelExec) -> Vec<Tensor> {
    exec.preset
        .params
        .iter()
        .enumerate()
        .map(|(t, info)| {
            let n = info.numel();
            let data: Vec<f32> = (0..n)
                .map(|k| (0.02 * (0.37 * k as f64 + t as f64).sin()) as f32)
                .collect();
            Tensor::from_vec(&info.shape, data)
        })
        .collect()
}

fn fixture_batch(exec: &ModelExec) -> Batch {
    let (b, s) = (exec.preset.batch, exec.preset.seq);
    let v = exec.preset.vocab as i64;
    let n = b * s;
    Batch {
        tokens: (0..n).map(|i| ((7 * i as i64 + 3) % v) as i32).collect(),
        targets: (0..n).map(|i| ((7 * (i as i64 + 1) + 3) % v) as i32).collect(),
        loss_mask: vec![1.0; n],
        batch: b,
        seq: s,
    }
}

#[test]
fn fixture_numerics_match_python() {
    // THE cross-language contract: same inputs through the compiled
    // artifact must reproduce jax's numbers from fixtures.json.
    let Some(rt) = runtime() else { return };
    let exec = ModelExec::load(&rt, "tiny").unwrap();
    let fix_text =
        std::fs::read_to_string(Runtime::default_dir().join("fixtures.json")).unwrap();
    let fix = Json::parse(&fix_text).unwrap();
    let tiny = fix.get("tiny").expect("tiny fixture");
    let want_loss = tiny.get("loss").and_then(|x| x.as_f64()).unwrap();
    let want_head: Vec<i32> = tiny
        .get("preds_head")
        .and_then(|x| x.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    let want_sum = tiny.get("preds_sum").and_then(|x| x.as_f64()).unwrap() as i64;

    let params = fixture_params(&exec);
    let batch = fixture_batch(&exec);
    let (loss, preds) = exec.eval_step(&params, &batch).unwrap();
    assert!(
        ((loss as f64) - want_loss).abs() < 1e-4 * want_loss.abs().max(1.0),
        "loss {loss} vs python {want_loss}"
    );
    assert_eq!(&preds[..32], &want_head[..], "first 32 predictions");
    let sum: i64 = preds.iter().map(|&p| p as i64).sum();
    assert_eq!(sum, want_sum, "prediction checksum");
}

#[test]
fn train_step_grads_are_consistent_with_loss() {
    // finite-difference check through the AOT train_step on one weight
    let Some(rt) = runtime() else { return };
    let exec = ModelExec::load(&rt, "tiny").unwrap();
    let mut params = fixture_params(&exec);
    let batch = fixture_batch(&exec);
    let (_, grads) = exec.train_step(&params, &batch).unwrap();
    // pick the steepest entry of one matrix for a robust fd check
    let pi = 2; // l0.wq
    let (gi, gmax) = grads[pi]
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, &g)| (i, g))
        .unwrap();
    let eps = 2e-2f32;
    params[pi].data[gi] += eps;
    let (lp, _) = exec.eval_step(&params, &batch).unwrap();
    params[pi].data[gi] -= 2.0 * eps;
    let (lm, _) = exec.eval_step(&params, &batch).unwrap();
    let fd = (lp - lm) / (2.0 * eps);
    assert!(
        (fd - gmax).abs() < 0.15 * gmax.abs().max(1e-3),
        "fd {fd} vs grad {gmax}"
    );
}

#[test]
fn svd_artifact_matches_rust_built_graph() {
    // the Pallas subspace-iteration artifact and the XlaBuilder graph are
    // the same algorithm; same inputs must give (near-)identical factors
    let Some(rt) = runtime() else { return };
    let la = Linalg::new(&rt.client);
    let mut rng = Rng::new(3);
    let (m, n, rp) = (128usize, 128usize, 40usize);
    let w = Tensor::randn(&[m, n], 0.05, &mut rng);
    let g0 = Tensor::randn(&[n, rp], 1.0, &mut rng);

    let file = rt.manifest.kernels.get("svd_128x128_r40").unwrap();
    let exe = rt.load_artifact(file).unwrap();
    let parts = rt
        .run_tuple(
            &exe,
            &[
                lift::runtime::literal::tensor_to_literal(&w).unwrap(),
                lift::runtime::literal::tensor_to_literal(&g0).unwrap(),
            ],
        )
        .unwrap();
    let q_k = lift::runtime::literal::literal_to_tensor(&parts[0]).unwrap();
    let b_k = lift::runtime::literal::literal_to_tensor(&parts[1]).unwrap();

    let (q_r, b_r) = la.svd_lowrank_with(&w, &g0, 2).unwrap();
    let dq = lift::util::stats::frobenius_diff(&q_k.data, &q_r.data);
    let db = lift::util::stats::frobenius_diff(&b_k.data, &b_r.data);
    assert!(dq < 1e-2, "Q mismatch {dq}");
    assert!(db < 1e-2 * b_r.frobenius().max(1.0), "B mismatch {db}");
    // and the reconstructions agree tightly
    let rec_k = la.matmul(&q_k, &b_k).unwrap();
    let rec_r = la.matmul(&q_r, &b_r).unwrap();
    let dr = lift::util::stats::frobenius_diff(&rec_k.data, &rec_r.data);
    assert!(dr < 1e-3 * rec_r.frobenius().max(1.0), "reconstruction {dr}");
}

#[test]
fn mask_artifact_matches_host_mask() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let (m, n, rp) = (128usize, 128usize, 40usize);
    let u = Tensor::randn(&[m, rp], 1.0, &mut rng);
    let v = Tensor::randn(&[n, rp], 1.0, &mut rng);
    let thr = 6.0f32;
    let file = rt.manifest.kernels.get("mask_128x128_r40").unwrap();
    let exe = rt.load_artifact(file).unwrap();
    let parts = rt
        .run_tuple(
            &exe,
            &[
                lift::runtime::literal::tensor_to_literal(&u).unwrap(),
                lift::runtime::literal::tensor_to_literal(&v).unwrap(),
                lift::runtime::literal::tensor_to_literal(&Tensor::from_vec(
                    &[1, 1],
                    vec![thr],
                ))
                .unwrap(),
            ],
        )
        .unwrap();
    let mask = lift::runtime::literal::literal_to_tensor(&parts[0]).unwrap();
    let counts = lift::runtime::literal::literal_to_vec_i32(&parts[1]).unwrap();
    // host oracle
    let vt = v.transpose();
    let wr = u.matmul(&vt);
    let mut host_count = 0;
    for i in 0..m * n {
        let want = if wr.data[i].abs() >= thr { 1.0 } else { 0.0 };
        assert_eq!(mask.data[i], want, "mask[{i}]");
        host_count += want as i32;
    }
    assert_eq!(counts.iter().sum::<i32>(), host_count);
}

#[test]
fn sparse_adam_kernel_matches_host_optimizer() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(5);
    let k = 1000usize;
    let cfg = AdamCfg::default();
    let kern = KernelAdam::new(&rt, k).unwrap();
    let mut p1 = rng.normal_vec(k, 1.0);
    let g = rng.normal_vec(k, 1.0);
    let mut m1 = vec![0.0f32; k];
    let mut v1 = vec![0.0f32; k];
    // host reference over the same packed vectors
    let mut host = SparseAdam::new((0..k as u32).collect(), cfg);
    let mut p2 = p1.clone();
    for t in 1..=3 {
        kern.step(&mut p1, &g, &mut m1, &mut v1, &cfg, t, 1e-3).unwrap();
        host.step(&mut p2, &g, 1e-3);
        for i in 0..k {
            assert!(
                (p1[i] - p2[i]).abs() < 1e-5,
                "step {t} idx {i}: {} vs {}",
                p1[i],
                p2[i]
            );
        }
    }
}

#[test]
fn lift_training_reduces_loss_and_respects_mask() {
    let Some(rt) = runtime() else { return };
    let exec = ModelExec::load(&rt, "tiny").unwrap();
    let mut rng = Rng::new(11);
    let mut params = model::init_params(&exec.preset, &mut rng);
    let before = params.clone();
    let corpus = pretrain::world(&exec);
    let sets = vec![TaskSet::generate(
        TaskFamily::AddSub,
        &corpus.vocab,
        &corpus.kg,
        200,
        40,
        1,
    )];
    let mut src = TaskMixSource {
        sets,
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, 1);
    let mut method = make_method(
        "lift",
        16,
        lift::lift::LiftCfg {
            rank: 16,
            ..Default::default()
        },
        0, // fixed mask: makes the invariant below exact
        Scope::default(),
    )
    .unwrap();
    let cfg = TrainCfg {
        steps: 20,
        lr: 1e-3,
        warmup_frac: 0.1,
        log_every: 0,
        seed: 1,
        ..Default::default()
    };
    let log = train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg).unwrap();
    assert!(
        log.tail_loss(5) < log.losses[0],
        "loss should drop: {} -> {}",
        log.losses[0],
        log.tail_loss(5)
    );
    // sparsity invariant: non-matrix params untouched; per-matrix change
    // count <= its budget
    for (pi, info) in exec.preset.params.iter().enumerate() {
        let changed = params[pi]
            .data
            .iter()
            .zip(&before[pi].data)
            .filter(|(a, b)| a != b)
            .count();
        if info.is_matrix() {
            let budget =
                lift::lift::budget_for(info.shape[0], info.shape[1], 16);
            assert!(changed <= budget, "{}: {changed} > {budget}", info.name);
            assert!(changed > 0, "{}: mask never trained", info.name);
        } else {
            assert_eq!(changed, 0, "{} must stay frozen", info.name);
        }
    }
}

#[test]
fn every_method_trains_without_error() {
    let Some(rt) = runtime() else { return };
    let exec = ModelExec::load(&rt, "tiny").unwrap();
    let corpus = pretrain::world(&exec);
    let sets = vec![TaskSet::generate(
        TaskFamily::BoolQ,
        &corpus.vocab,
        &corpus.kg,
        100,
        20,
        1,
    )];
    for name in [
        "full", "lift", "lift_mlp", "lift_structured", "weight_mag", "grad_mag",
        "movement", "random", "sift", "spiel", "lora", "pissa", "dora",
        "spectral", "s2ft",
    ] {
        let mut rng = Rng::new(7);
        let mut params = model::init_params(&exec.preset, &mut rng);
        let mut src = TaskMixSource {
            sets: sets.clone(),
            batch: exec.preset.batch,
            seq: exec.preset.seq,
        };
        let mut ctx = pretrain::make_ctx(&rt, &exec, 7);
        let mut method = make_method(
            name,
            8,
            lift::lift::LiftCfg {
                rank: 8,
                ..Default::default()
            },
            5,
            Scope::default(),
        )
        .unwrap();
        let cfg = TrainCfg {
            steps: 8,
            lr: 5e-4,
            warmup_frac: 0.1,
            log_every: 0,
            seed: 7,
            ..Default::default()
        };
        let log =
            train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg).unwrap();
        assert!(log.losses.iter().all(|l| l.is_finite()), "{name} diverged");
        assert!(method.trainable() > 0, "{name} trains nothing");
        if name != "full" {
            // budget sanity: all PEFT/sparse methods train << all params
            assert!(
                method.trainable() < exec.preset.n_params() / 2,
                "{name} trains too much"
            );
        }
    }
}

#[test]
fn mask_refresh_migrates_state_during_training() {
    // run LIFT with a short refresh interval; training must stay finite
    // and the method must keep exactly the budgeted number of indices
    let Some(rt) = runtime() else { return };
    let exec = ModelExec::load(&rt, "tiny").unwrap();
    let corpus = pretrain::world(&exec);
    let sets = vec![TaskSet::generate(
        TaskFamily::Mawps,
        &corpus.vocab,
        &corpus.kg,
        100,
        20,
        1,
    )];
    let mut rng = Rng::new(9);
    let mut params = model::init_params(&exec.preset, &mut rng);
    let mut src = TaskMixSource {
        sets,
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, 9);
    let mut method = lift::methods::sparse_ft::SparseFt::new(
        "LIFT",
        lift::lift::Selector::Lift,
        8,
        lift::lift::LiftCfg {
            rank: 8,
            ..Default::default()
        },
        4, // refresh every 4 steps
        Scope::default(),
    );
    let cfg = TrainCfg {
        steps: 12,
        lr: 1e-3,
        warmup_frac: 0.0,
        log_every: 0,
        seed: 9,
        ..Default::default()
    };
    train(&exec, &mut src, &mut method, &mut ctx, &mut params, &cfg).unwrap();
    assert!(method.last_refresh_overlap > 0.0 && method.last_refresh_overlap <= 1.0);
    let budget_total: usize = exec
        .preset
        .params
        .iter()
        .filter(|p| p.is_matrix())
        .map(|p| lift::lift::budget_for(p.shape[0], p.shape[1], 8))
        .sum();
    assert_eq!(method.trainable(), budget_total);
}

//! Multi-runner lease protocol + ledger durability suite (ISSUE 6):
//!
//! * two uncoordinated runners racing one campaign on a shared
//!   directory compute every cell EXACTLY once and produce a merged
//!   ledger bit-identical (modulo the wall-clock `seconds` field) to a
//!   single-runner run;
//! * live foreign leases defer cells (reported, never recomputed);
//!   expired leases are taken over at a strictly higher fencing token,
//!   and the takeover's checkpoints land in the token-fenced dir;
//! * a runner that loses its lease mid-compute REFUSES to commit its
//!   outcome (stale-token write refusal) — the cell defers instead of
//!   racing the usurper's rename;
//! * ledger durability: the crash window between outcome-temp-write and
//!   rename leaves the prior outcome readable and the cell recomputable;
//! * an UNREADABLE outcome file (IO error, not bad bytes) aborts the
//!   campaign instead of classifying as corrupt and destroying finished
//!   work by recompute.
//!
//! Everything runs artifact-free on toy cells (`exp::matrix::synth_step`
//! through the real trainer loop). The single-file claim/renew/fence
//! state machine has its own unit suite in `rust/src/exp/lease.rs`.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use lift::exp::lease::{self, Claim, LeaseCfg};
use lift::exp::matrix::{self, CellOutcome, CellSpec, LedgerEntry};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lift_lease_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn toy_cells() -> Vec<CellSpec> {
    matrix::expand_grid(
        "toy",
        &["lift".to_string(), "full".to_string()],
        &[],
        &[2],
        &[1, 2],
        4,
        2,
    )
}

/// Hand-author a lease file (the tests' crashed/foreign-runner
/// injector). Field layout matches `exp::lease::Lease::to_json`.
fn put_lease(dir: &Path, id: &str, runner: &str, token: u64, expires_unix: u64) {
    std::fs::write(
        lease::lease_path(dir, id),
        format!("{{\"runner\":\"{runner}\",\"token\":{token},\"expires_unix\":{expires_unix}}}"),
    )
    .unwrap();
}

/// Outcomes compared across runs must ignore the one wall-clock field.
fn norm(mut o: CellOutcome) -> CellOutcome {
    o.seconds = 0.0;
    o
}

// ---- two runners, one campaign ------------------------------------------

/// The tentpole's acceptance test, in-process: two runners race every
/// cell of one campaign. Exactly-once compute, disjoint `ran` sets, a
/// merged ledger equal to the single-runner baseline modulo seconds,
/// token-fenced checkpoint dirs, and no leases left behind.
#[test]
fn two_runners_shard_a_campaign_exactly_once_and_match_single_runner() {
    let cells = toy_cells();
    // single-runner, lease-free baseline
    let base_dir = tmpdir("race_baseline");
    let report = matrix::run_matrix(&base_dir, &cells, 2, |s| {
        matrix::run_toy_cell(s, &base_dir, 2, 0, 1)
    })
    .unwrap();
    assert_eq!(report.ran.len(), cells.len());
    assert!(report.failed.is_empty() && report.deferred.is_empty());

    // two leased runners racing one shared directory
    let race_dir = tmpdir("race_shared");
    let computed = AtomicUsize::new(0);
    let reports: Vec<matrix::MatrixReport> = std::thread::scope(|s| {
        let handles: Vec<_> = ["runner_a", "runner_b"]
            .iter()
            .map(|name| {
                let race_dir = race_dir.clone();
                let cells = &cells;
                let computed = &computed;
                s.spawn(move || {
                    let cfg = LeaseCfg::new(name, 300);
                    matrix::run_matrix_with(&race_dir, cells, 2, Some(&cfg), |spec, ckpt_dir| {
                        computed.fetch_add(1, Ordering::SeqCst);
                        matrix::run_toy_cell_in(spec, ckpt_dir, 2, 0, 1)
                    })
                    .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // zero double-computed cells under live leases
    assert_eq!(
        computed.load(Ordering::SeqCst),
        cells.len(),
        "every cell must be computed exactly once across both runners"
    );
    let ran_a: std::collections::HashSet<&String> = reports[0].ran.iter().collect();
    let ran_b: std::collections::HashSet<&String> = reports[1].ran.iter().collect();
    assert!(ran_a.is_disjoint(&ran_b), "a cell ran on both runners");
    assert_eq!(ran_a.len() + ran_b.len(), cells.len());
    for r in &reports {
        assert!(r.failed.is_empty(), "{:?}", r.failed);
        // deferred cells are fine (the other runner held them) but each
        // must have landed via SOMEONE
        for (id, _) in &r.deferred {
            assert!(
                matrix::read_outcome(&race_dir, id).is_some(),
                "deferred cell {id} never landed"
            );
        }
    }
    for c in &cells {
        let id = c.id();
        // merged ledger == single-runner ledger, modulo wall-seconds
        let raced = matrix::read_outcome(&race_dir, &id).expect("raced cell missing");
        let baseline = matrix::read_outcome(&base_dir, &id).expect("baseline cell missing");
        assert_eq!(norm(raced), norm(baseline), "cell {id} diverged from single-runner");
        // all leases released after the campaign
        assert!(
            lease::read_lease(&race_dir, &id).is_none(),
            "cell {id} left a lease behind"
        );
        // fresh claims fence their checkpoints at token 1
        assert!(
            matrix::cell_ckpt_dir_fenced(&race_dir, &id, Some(1)).is_dir(),
            "cell {id} missing its token-fenced checkpoint dir"
        );
        assert!(
            !matrix::cell_ckpt_dir(&race_dir, &id).exists(),
            "cell {id} wrote to the unfenced checkpoint dir despite holding a lease"
        );
    }
    std::fs::remove_dir_all(&base_dir).unwrap();
    std::fs::remove_dir_all(&race_dir).unwrap();
}

// ---- takeover and deferral ----------------------------------------------

#[test]
fn live_foreign_lease_defers_the_cell_and_is_left_untouched() {
    let dir = tmpdir("defer");
    let cells = toy_cells();
    let busy_id = cells[0].id();
    let far = lease::now_unix().unwrap() + 3600;
    put_lease(&dir, &busy_id, "other_host", 2, far);
    let computed = AtomicUsize::new(0);
    let cfg = LeaseCfg::new("me", 300);
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), |spec, ckpt_dir| {
        computed.fetch_add(1, Ordering::SeqCst);
        matrix::run_toy_cell_in(spec, ckpt_dir, 0, 0, 1)
    })
    .unwrap();
    assert_eq!(computed.load(Ordering::SeqCst), cells.len() - 1);
    assert_eq!(report.deferred.len(), 1);
    assert_eq!(report.deferred[0].0, busy_id);
    assert!(report.deferred[0].1.contains("other_host"), "{:?}", report.deferred);
    assert!(matrix::read_outcome(&dir, &busy_id).is_none(), "deferred cell must not run");
    // the holder's lease is exactly as we planted it
    let l = lease::read_lease(&dir, &busy_id).unwrap();
    assert_eq!((l.runner.as_str(), l.token, l.expires_unix), ("other_host", 2, far));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn expired_lease_is_taken_over_and_checkpoints_under_the_new_token() {
    let dir = tmpdir("takeover");
    let cells = toy_cells();
    let dead_id = cells[0].id();
    put_lease(&dir, &dead_id, "crashed_host", 4, lease::now_unix().unwrap().saturating_sub(30));
    let cfg = LeaseCfg::new("me", 300);
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), |spec, ckpt_dir| {
        matrix::run_toy_cell_in(spec, ckpt_dir, 2, 0, 1)
    })
    .unwrap();
    assert_eq!(report.ran.len(), cells.len(), "takeover must recover the cell");
    assert!(matrix::read_outcome(&dir, &dead_id).is_some());
    assert!(lease::read_lease(&dir, &dead_id).is_none(), "takeover lease must be released");
    // provable fencing: the takeover ran at token 5 = crashed holder's 4 + 1,
    // so its snapshots are isolated from the zombie's dir
    assert!(
        matrix::cell_ckpt_dir_fenced(&dir, &dead_id, Some(5)).is_dir(),
        "takeover checkpoints must land under the token-5 dir"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn reused_runner_id_reclaims_its_own_leases_at_the_same_token() {
    // the kill/resume story: a restarted runner with a stable
    // --runner-id picks its cells back up immediately — same token, so
    // the SAME fenced checkpoint dir and its snapshots resume
    let dir = tmpdir("reclaim");
    let cells = toy_cells();
    let mine = cells[1].id();
    put_lease(&dir, &mine, "ci", 3, lease::now_unix().unwrap() + 3600);
    let cfg = LeaseCfg::new("ci", 300);
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), |spec, ckpt_dir| {
        matrix::run_toy_cell_in(spec, ckpt_dir, 2, 0, 1)
    })
    .unwrap();
    assert_eq!(report.ran.len(), cells.len(), "own live lease must not defer");
    assert!(
        matrix::cell_ckpt_dir_fenced(&dir, &mine, Some(3)).is_dir(),
        "reclaim must keep the original token's checkpoint dir"
    );
    assert!(lease::read_lease(&dir, &mine).is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn losing_the_lease_mid_compute_refuses_the_commit() {
    let dir = tmpdir("stale_commit");
    let cells = toy_cells();
    let target = cells[0].id();
    let cfg = LeaseCfg::new("me", 300);
    let dir2 = dir.clone();
    let target2 = target.clone();
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), move |spec, ckpt_dir| {
        if spec.id() == target2 {
            // a takeover lands while this cell computes (as if our TTL
            // expired under a long stall)
            put_lease(&dir2, &target2, "usurper", 99, lease::now_unix().unwrap() + 3600);
        }
        matrix::run_toy_cell_in(spec, ckpt_dir, 0, 0, 1)
    })
    .unwrap();
    // the displaced cell is deferred (not failed), its outcome is NOT
    // written, and the usurper's lease survives
    assert_eq!(report.deferred.len(), 1, "{:?}", report.deferred);
    assert_eq!(report.deferred[0].0, target);
    assert!(report.deferred[0].1.contains("lease lost"), "{:?}", report.deferred);
    assert!(report.failed.is_empty());
    assert_eq!(report.ran.len(), cells.len() - 1);
    assert!(
        matrix::read_outcome(&dir, &target).is_none(),
        "stale-token runner must refuse its write"
    );
    assert_eq!(lease::read_lease(&dir, &target).unwrap().runner, "usurper");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn leftover_lease_on_a_finished_cell_is_garbage_collected() {
    // crash window between outcome-commit and lease-release: the next
    // classify pass must free the id (ours or expired only)
    let dir = tmpdir("gc");
    let cells = toy_cells();
    let cfg = LeaseCfg::new("me", 300);
    // finish every cell lease-free, then strand a lease on one
    matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1)).unwrap();
    let stranded = cells[2].id();
    put_lease(&dir, &stranded, "me", 1, lease::now_unix().unwrap() + 3600);
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), |spec, ckpt_dir| {
        matrix::run_toy_cell_in(spec, ckpt_dir, 0, 0, 1)
    })
    .unwrap();
    assert_eq!(report.skipped.len(), cells.len(), "all cells were already done");
    assert!(report.ran.is_empty());
    assert!(lease::read_lease(&dir, &stranded).is_none(), "stranded lease must be collected");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- outcome durability --------------------------------------------------

#[test]
fn torn_tmp_next_to_a_committed_outcome_leaves_it_readable() {
    // the post-crash disk state of "died between tmp-write and rename"
    // AFTER a previous successful commit: the prior outcome must stay
    // the ledger's truth and the stale temp must be inert
    let dir = tmpdir("torn_after_commit");
    let cells = toy_cells();
    let id = cells[0].id();
    matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1)).unwrap();
    let committed = matrix::read_outcome(&dir, &id).expect("cell finished");
    // torn temps from a lease-free writer AND from two fenced runners
    std::fs::write(dir.join(format!("{id}.json.tmp")), b"{\"v\":2,\"label\":\"to").unwrap();
    std::fs::write(dir.join(format!("{id}.json.r1.t1.tmp")), b"garbage").unwrap();
    assert!(
        matches!(matrix::classify_outcome(&dir, &id), LedgerEntry::Done(_)),
        "stale temp files must not shadow the committed outcome"
    );
    assert_eq!(matrix::read_outcome(&dir, &id).unwrap(), committed);
    // a rerun changes nothing: the cell is skipped, the outcome is
    // byte-identical afterwards
    let before = std::fs::read(matrix::outcome_path(&dir, &id)).unwrap();
    let report =
        matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1)).unwrap();
    assert!(report.skipped.contains(&id));
    assert_eq!(std::fs::read(matrix::outcome_path(&dir, &id)).unwrap(), before);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_tmp_with_no_outcome_leaves_the_cell_recomputable() {
    // crash before the FIRST commit of a cell: a stale temp alone must
    // read as not-done, and the recompute must land cleanly over it
    let dir = tmpdir("torn_before_commit");
    let cells = toy_cells();
    let id = cells[0].id();
    std::fs::write(dir.join(format!("{id}.json.tmp")), b"{\"v\":2,\"tr").unwrap();
    assert!(matches!(matrix::classify_outcome(&dir, &id), LedgerEntry::Missing));
    let report =
        matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1)).unwrap();
    assert!(report.ran.contains(&id), "cell with only a torn temp must recompute");
    assert!(matrix::read_outcome(&dir, &id).is_some());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn unreadable_outcome_aborts_instead_of_recomputing() {
    // an IO-level read failure is NOT corruption: the file may hold
    // finished work. A directory at the outcome path yields EISDIR on
    // read — a non-NotFound error — standing in for EACCES/EIO (which
    // a root-owned test process cannot provoke via permissions).
    let dir = tmpdir("unreadable");
    let cells = toy_cells();
    let id = cells[0].id();
    std::fs::create_dir_all(matrix::outcome_path(&dir, &id)).unwrap();
    match matrix::classify_outcome(&dir, &id) {
        LedgerEntry::Unreadable(why) => assert!(why.contains(&id), "{why}"),
        other => panic!("expected Unreadable, got {other:?}"),
    }
    // rendering treats it as unfinished…
    assert!(matrix::read_outcome(&dir, &id).is_none());
    // …but the campaign refuses to run over it
    let err = matrix::run_matrix(&dir, &cells, 1, |s| matrix::run_toy_cell(s, &dir, 0, 0, 1))
        .unwrap_err()
        .to_string();
    assert!(err.contains("could not be read"), "{err}");
    assert!(err.contains(&id), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn unreadable_lease_defers_the_cell_instead_of_claiming_over_it() {
    // the lease-side twin of unreadable_outcome_aborts: a lease file
    // whose BYTES cannot be read (EISDIR via a directory at the path,
    // standing in for EACCES/EIO) proves nothing about the holder. The
    // old `.ok()?` fold read it as "no lease" and claimed the cell —
    // racing a possibly-live runner. It must defer loudly instead.
    let dir = tmpdir("unreadable_lease");
    let cells = toy_cells();
    let blocked = cells[0].id();
    std::fs::create_dir_all(lease::lease_path(&dir, &blocked)).unwrap();
    // the direct claim API names the distinct state
    match lease::claim(&dir, &blocked, &LeaseCfg::new("me", 300)).unwrap() {
        Claim::Unreadable { why } => assert!(why.contains(&blocked), "{why}"),
        other => panic!("expected Claim::Unreadable, got {other:?}"),
    }
    // checked read errors; the permissive view folds to None for renderers
    assert!(lease::read_lease_checked(&dir, &blocked).is_err());
    assert!(lease::read_lease(&dir, &blocked).is_none());
    // a campaign defers the blocked cell and still lands all the others
    let computed = AtomicUsize::new(0);
    let cfg = LeaseCfg::new("me", 300);
    let report = matrix::run_matrix_with(&dir, &cells, 1, Some(&cfg), |spec, ckpt_dir| {
        computed.fetch_add(1, Ordering::SeqCst);
        matrix::run_toy_cell_in(spec, ckpt_dir, 0, 0, 1)
    })
    .unwrap();
    assert_eq!(computed.load(Ordering::SeqCst), cells.len() - 1);
    assert_eq!(report.deferred.len(), 1, "{:?}", report.deferred);
    assert_eq!(report.deferred[0].0, blocked);
    assert!(report.deferred[0].1.contains("lease unreadable"), "{:?}", report.deferred);
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    assert!(
        matrix::read_outcome(&dir, &blocked).is_none(),
        "the blocked cell must not have been computed over an unreadable lease"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- direct claim API over a campaign dir --------------------------------

#[test]
fn claim_tokens_escalate_across_successive_takeovers() {
    // fencing tokens must be monotonic over the WHOLE cell history, not
    // per-runner: crash chains r1 -> r2 -> r3 yield tokens 1, 2, 3
    let dir = tmpdir("escalate");
    let mut expect = 0u64;
    for runner in ["r1", "r2", "r3"] {
        let cfg = LeaseCfg::new(runner, 1);
        let Claim::Held(g) = lease::claim(&dir, "cell", &cfg).unwrap() else {
            panic!("{runner} should claim");
        };
        expect += 1;
        assert_eq!(g.token(), expect, "{runner} got the wrong fencing token");
        // expire the lease in place so the next runner takes over
        // (TTL floor is 1s; rewrite the deadline instead of sleeping)
        put_lease(&dir, "cell", runner, expect, lease::now_unix().unwrap().saturating_sub(5));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

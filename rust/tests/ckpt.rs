//! Crash-resume determinism suite (ISSUE 3):
//!
//! * for every `Method`, 2k steps straight vs. checkpoint-at-step-k +
//!   restore-into-fresh-objects + continue must be **bit-identical** on
//!   weights AND optimizer moments (`state_digest`), at 1 worker and at
//!   the default worker count (CI additionally reruns this whole suite
//!   under `LIFT_WORKERS=1`);
//! * corruption/compat: truncated snapshots and flipped bytes are
//!   rejected by the CRC32 layer with a specific error, a bumped format
//!   version fails loudly instead of misparsing, and the codec
//!   round-trips randomized degenerate shapes (m=1, n=1, empty masks);
//! * the scenario-matrix runner skips finished cells, recomputes only
//!   deleted/corrupted ones, and resumes interrupted cells from their
//!   newest snapshot.
//!
//! Everything here runs without AOT artifacts: the trainer loop is
//! driven through `train::train_with` with the synthetic gradient
//! stream (`exp::matrix::synth_step`), which is the same loop — same
//! checkpoint cadence, same resume path — the production `ModelExec`
//! source uses.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use lift::ckpt::{self, Snapshot};
use lift::exp::matrix::{self, CellSpec};
use lift::lift::LiftCfg;
use lift::methods::{digest_words, make_method, Ctx, Method, Scope};
use lift::optim::AdamCfg;
use lift::runtime::Linalg;
use lift::tensor::Tensor;
use lift::train::{train_with, TrainCfg, TrainLog};
use lift::util::prop::{check, ensure};
use lift::util::rng::Rng;

/// Every method name `make_method` accepts.
const ALL_METHODS: [&str; 15] = [
    "lift",
    "lift_mlp",
    "lift_structured",
    "weight_mag",
    "grad_mag",
    "movement",
    "random",
    "sift",
    "spiel",
    "full",
    "lora",
    "pissa",
    "dora",
    "spectral",
    "s2ft",
];

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lift_ckpt_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make(name: &str) -> Box<dyn Method> {
    make_method(
        name,
        4,
        LiftCfg {
            rank: 4,
            ..Default::default()
        },
        2, // refresh every 2 steps: migrations straddle the crash point
        Scope::default(),
    )
    .unwrap()
}

fn base_cfg(steps: usize) -> TrainCfg {
    TrainCfg {
        steps,
        lr: 1e-3,
        warmup_frac: 0.03,
        log_every: 0,
        seed: 5,
        ckpt_every: 0,
        ckpt_dir: None,
        ckpt_keep: 0,
    }
}

fn weight_digest(params: &[Tensor]) -> u64 {
    digest_words(
        params
            .iter()
            .flat_map(|t| t.data.iter().map(|x| x.to_bits() as u64)),
    )
}

/// An uninterrupted run: fresh method, `steps` trainer steps.
fn run_straight(name: &str, workers: usize, steps: usize) -> (u64, u64, TrainLog) {
    let mut ctx = matrix::toy_ctx(workers, 0xC0FFEE).unwrap();
    let mut params = matrix::toy_params(0x1717);
    let mut method = make(name);
    let log = train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &base_cfg(steps),
        None,
    )
    .unwrap();
    (weight_digest(&params), method.state_digest(), log)
}

/// A "crashed" run: the FULL config (so the LR schedule matches the
/// straight run), interrupted by a gradient source that dies after `k`
/// steps — exactly like a preemption mid-step — then fresh ctx / fresh
/// (differently-initialized) params / fresh method restored from the
/// snapshot and continued to `total`. Restore must overwrite every piece
/// of state, which is why phase 2 deliberately starts from wrong seeds.
fn run_resumed(name: &str, workers: usize, k: usize, total: usize, dir: &Path) -> (u64, u64, TrainLog) {
    {
        let mut ctx = matrix::toy_ctx(workers, 0xC0FFEE).unwrap();
        let mut params = matrix::toy_params(0x1717);
        let mut method = make(name);
        let cfg = TrainCfg {
            ckpt_every: k,
            ckpt_dir: Some(dir.to_path_buf()),
            ..base_cfg(total)
        };
        let mut served = 0usize;
        let mut crashing = |params: &[Tensor], rng: &mut Rng| {
            if served == k {
                anyhow::bail!("simulated crash");
            }
            served += 1;
            matrix::synth_step(params, rng)
        };
        let err = train_with(&mut crashing, &mut *method, &mut ctx, &mut params, &cfg, None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("simulated crash"));
    }
    let snap = ckpt::latest_snapshot(dir).unwrap().expect("snapshot written at step k");
    let mut ctx = matrix::toy_ctx(workers, 0xDEAD_BEEF).unwrap();
    let mut params = matrix::toy_params(0x9999);
    let mut method = make(name);
    let log = train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &base_cfg(total),
        Some(&snap),
    )
    .unwrap();
    (weight_digest(&params), method.state_digest(), log)
}

#[test]
fn every_method_crash_resumes_bit_identically() {
    let init = weight_digest(&matrix::toy_params(0x1717));
    let default_workers = lift::lift::engine::default_workers().max(2);
    for name in ALL_METHODS {
        for workers in [1usize, default_workers] {
            let (ws, ss, _) = run_straight(name, workers, 6);
            let dir = tmpdir(&format!("resume_{name}_{workers}w"));
            let (wr, sr, _) = run_resumed(name, workers, 3, 6, &dir);
            assert_eq!(ws, wr, "{name}/{workers}w: weights diverged after resume");
            assert_eq!(ss, sr, "{name}/{workers}w: optimizer state diverged after resume");
            assert_ne!(ws, init, "{name}/{workers}w: nothing trained");
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
}

#[test]
fn resume_replays_the_loss_curve_exactly() {
    // the snapshot carries the full log prefix and both RNG positions,
    // so the resumed curve must equal the straight one bit-for-bit, and
    // the restored log must cover the whole campaign (losses AND
    // per-step latencies), not just the post-crash tail
    let (_, _, straight) = run_straight("lift", 2, 6);
    let dir = tmpdir("loss_curve");
    let (_, _, resumed) = run_resumed("lift", 2, 3, 6, &dir);
    assert_eq!(straight.losses.len(), resumed.losses.len());
    for (i, (a, b)) in straight.losses.iter().zip(&resumed.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss {i} differs: {a} vs {b}");
    }
    assert_eq!(
        resumed.step_times.len(),
        resumed.losses.len(),
        "restored log must keep step_times paired with losses"
    );
    assert!(resumed.seconds > 0.0, "campaign wall time must accumulate");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn method_state_roundtrips_mid_run_for_every_method() {
    for name in ALL_METHODS {
        let mut ctx = matrix::toy_ctx(2, 0xC0FFEE).unwrap();
        let mut params = matrix::toy_params(0x1717);
        let mut method = make(name);
        train_with(
            &mut matrix::synth_step,
            &mut *method,
            &mut ctx,
            &mut params,
            &base_cfg(3),
            None,
        )
        .unwrap();
        let bytes = method.save_state().unwrap();
        let mut fresh = make(name);
        fresh.load_state(&bytes).unwrap();
        assert_eq!(
            fresh.state_digest(),
            method.state_digest(),
            "{name}: state digest changed across save/load"
        );
        assert_eq!(
            fresh.save_state().unwrap(),
            bytes,
            "{name}: re-serialization is not byte-stable"
        );
        assert_eq!(fresh.trainable(), method.trainable(), "{name}: trainable drifted");
        assert_eq!(fresh.opt_bytes(), method.opt_bytes(), "{name}: opt_bytes drifted");
        // cross-method loads are rejected, not misparsed
        let other = if name == "full" { "lift" } else { "full" };
        assert!(
            make(other).load_state(&bytes).is_err(),
            "{other} accepted {name}'s state"
        );
    }
}

#[test]
fn load_state_rejects_a_different_spec() {
    // same method label, different rank / interval: must refuse instead
    // of silently resuming the old state as a hybrid run
    let mut ctx = matrix::toy_ctx(1, 0xC0FFEE).unwrap();
    let mut params = matrix::toy_params(0x1717);
    let mut method = make("lift"); // rank 4, interval 2
    train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &base_cfg(2),
        None,
    )
    .unwrap();
    let bytes = method.save_state().unwrap();
    let lra = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    let mut wrong_rank = make_method("lift", 8, lra, 2, Scope::default()).unwrap();
    assert!(wrong_rank.load_state(&bytes).is_err(), "rank mismatch accepted");
    let mut wrong_interval = make_method("lift", 4, lra, 5, Scope::default()).unwrap();
    assert!(
        wrong_interval.load_state(&bytes).is_err(),
        "interval mismatch accepted"
    );
    let mut sp = make("spiel");
    train_with(
        &mut matrix::synth_step,
        &mut *sp,
        &mut ctx,
        &mut params,
        &base_cfg(2),
        None,
    )
    .unwrap();
    let sp_bytes = sp.save_state().unwrap();
    let mut sp_wrong = make_method("spiel", 8, lra, 2, Scope::default()).unwrap();
    assert!(sp_wrong.load_state(&sp_bytes).is_err(), "SpIEL rank mismatch accepted");
}

#[test]
fn resume_rejects_a_different_train_cfg() {
    // a changed lr or total-steps changes the LR schedule — resume must
    // refuse instead of silently diverging from the uninterrupted run
    let dir = tmpdir("cfg_mismatch");
    let path = sample_snapshot(&dir); // written under base_cfg(2)
    let mut ctx = matrix::toy_ctx(1, 1).unwrap();
    let mut params = matrix::toy_params(0x1717);
    let mut method = make("lift");
    let wrong_lr = TrainCfg {
        lr: 5e-4,
        ..base_cfg(2)
    };
    let err = train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &wrong_lr,
        Some(&path),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("TrainCfg"), "{err:#}");
    let mut method2 = make("lift");
    let err2 = train_with(
        &mut matrix::synth_step,
        &mut *method2,
        &mut ctx,
        &mut params,
        &base_cfg(4), // different schedule total
        Some(&path),
    )
    .unwrap_err();
    assert!(format!("{err2:#}").contains("TrainCfg"), "{err2:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- hot-loop overhaul: warm carriers, retention, flat snapshots --------

/// A preset whose matrices have a min side of 40+, so the exact top-r
/// subspace path engages (2(rank + oversample) < min(m, n)) and warm
/// carriers are actually produced — the toy preset's 16-wide matrices
/// always take the full-Jacobi fallback, which carries none.
fn wide_preset() -> lift::runtime::manifest::PresetInfo {
    use lift::runtime::manifest::{ParamInfo, PresetInfo};
    let mut params = vec![ParamInfo {
        name: "embed".into(),
        shape: vec![32, 16],
    }];
    for (kind, shape) in [
        ("wq", vec![48usize, 40usize]),
        ("wk", vec![40, 48]),
        ("wup", vec![40, 64]),
        ("wdown", vec![64, 40]),
    ] {
        params.push(ParamInfo {
            name: format!("l0.{kind}"),
            shape,
        });
    }
    PresetInfo {
        name: "wide".into(),
        d: 40,
        layers: 1,
        ffn: 64,
        vocab: 32,
        seq: 8,
        batch: 2,
        heads: 2,
        params,
        executables: Default::default(),
    }
}

fn wide_ctx(workers: usize, seed: u64) -> Ctx {
    Ctx {
        la: Arc::new(Linalg::new(&xla::PjRtClient::cpu().unwrap())),
        preset: wide_preset(),
        rng: Rng::new(seed),
        adam: AdamCfg::default(),
        workers,
    }
}

fn wide_params(seed: u64) -> Vec<Tensor> {
    lift::model::init_params(&wide_preset(), &mut Rng::new(seed))
}

/// Exact-path LIFT (refresh every 2 steps): its refreshes run the
/// warm-started subspace iteration and persist the carriers.
fn make_exact_lift() -> Box<dyn Method> {
    make_method(
        "lift",
        4,
        LiftCfg {
            rank: 4,
            exact: true,
            ..Default::default()
        },
        2,
        Scope::default(),
    )
    .unwrap()
}

#[test]
fn warm_carriers_crash_resume_bit_identically() {
    // straight vs crash-at-3 + restore + continue, on the wide preset
    // where warm carriers exist. `state_digest` hashes the carriers
    // themselves, so a resume that dropped or perturbed them — leaving
    // the post-resume refresh to re-converge cold, within tolerance but
    // not bitwise — fails this test even if the masks happen to agree.
    let (total, k) = (6usize, 3usize);
    for workers in [1usize, lift::lift::engine::default_workers().max(2)] {
        let (ws, ss, straight_bytes) = {
            let mut ctx = wide_ctx(workers, 0xC0FFEE);
            let mut params = wide_params(0x1717);
            let mut method = make_exact_lift();
            train_with(
                &mut matrix::synth_step,
                &mut *method,
                &mut ctx,
                &mut params,
                &base_cfg(total),
                None,
            )
            .unwrap();
            (weight_digest(&params), method.state_digest(), method.save_state().unwrap())
        };
        let dir = tmpdir(&format!("warm_resume_{workers}w"));
        {
            let mut ctx = wide_ctx(workers, 0xC0FFEE);
            let mut params = wide_params(0x1717);
            let mut method = make_exact_lift();
            let cfg = TrainCfg {
                ckpt_every: k,
                ckpt_dir: Some(dir.clone()),
                ..base_cfg(total)
            };
            let mut served = 0usize;
            let mut crashing = |params: &[Tensor], rng: &mut Rng| {
                if served == k {
                    anyhow::bail!("simulated crash");
                }
                served += 1;
                matrix::synth_step(params, rng)
            };
            train_with(&mut crashing, &mut *method, &mut ctx, &mut params, &cfg, None)
                .unwrap_err();
        }
        let snap = ckpt::latest_snapshot(&dir).unwrap().expect("snapshot at k");
        let mut ctx = wide_ctx(workers, 0xDEAD_BEEF);
        let mut params = wide_params(0x9999);
        let mut method = make_exact_lift();
        train_with(
            &mut matrix::synth_step,
            &mut *method,
            &mut ctx,
            &mut params,
            &base_cfg(total),
            Some(&snap),
        )
        .unwrap();
        assert_eq!(ws, weight_digest(&params), "{workers}w: weights diverged");
        assert_eq!(ss, method.state_digest(), "{workers}w: state (incl. warm carriers) diverged");
        assert_eq!(
            straight_bytes,
            method.save_state().unwrap(),
            "{workers}w: serialized state diverged after resume"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn snapshot_bytes_are_flat_in_step_count() {
    // the sidecar satellite's regression test: with the curve streamed
    // to curve.sidecar, a snapshot at step 40 must be byte-for-byte the
    // same SIZE as the one at step 5 — O(model), not O(model + steps)
    let dir = tmpdir("flat_size");
    let mut ctx = matrix::toy_ctx(1, 3).unwrap();
    let mut params = matrix::toy_params(3);
    let mut method = make("lift");
    let cfg = TrainCfg {
        ckpt_every: 5,
        ckpt_dir: Some(dir.clone()),
        ..base_cfg(40)
    };
    train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &cfg,
        None,
    )
    .unwrap();
    let size = |step: usize| std::fs::metadata(ckpt::snapshot_path(&dir, step)).unwrap().len();
    assert_eq!(
        size(5),
        size(40),
        "snapshot bytes grew with step count — the curve leaked back into the snapshot"
    );
    // the curve lives in the sidecar instead: 8-byte magic + 12 B/step
    let side = std::fs::metadata(lift::ckpt::curve::curve_path(&dir)).unwrap().len();
    assert_eq!(side, 8 + 40 * 12);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn retention_caps_the_directory_and_resume_still_restores_the_campaign() {
    let dir = tmpdir("retention");
    {
        let mut ctx = matrix::toy_ctx(2, 0xC0FFEE).unwrap();
        let mut params = matrix::toy_params(0x1717);
        let mut method = make("lift");
        let cfg = TrainCfg {
            ckpt_every: 1,
            ckpt_dir: Some(dir.clone()),
            ckpt_keep: 3,
            ..base_cfg(7)
        };
        train_with(
            &mut matrix::synth_step,
            &mut *method,
            &mut ctx,
            &mut params,
            &cfg,
            None,
        )
        .unwrap();
    }
    let mut snaps: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".snap"))
        .collect();
    snaps.sort();
    assert_eq!(
        snaps,
        vec!["step_00000005.snap", "step_00000006.snap", "step_00000007.snap"],
        "keep-last-3 must bound the directory"
    );
    assert!(
        lift::ckpt::curve::curve_path(&dir).exists(),
        "the sidecar is never pruned"
    );
    // resuming from the newest retained snapshot reconstructs the FULL
    // campaign curve from the sidecar, including pruned steps' records
    let snap = ckpt::latest_snapshot(&dir).unwrap().unwrap();
    let mut ctx = matrix::toy_ctx(2, 1).unwrap();
    let mut params = matrix::toy_params(9);
    let mut method = make("lift");
    let log = train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &base_cfg(7),
        Some(&snap),
    )
    .unwrap();
    assert_eq!(log.losses.len(), 7);
    assert_eq!(log.step_times.len(), 7);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fresh_run_refuses_a_directory_with_newer_snapshots() {
    // opening the curve sidecar rewrites it; a run whose start is
    // behind an existing snapshot would orphan that snapshot's curve
    // records — the trainer must refuse loudly, not truncate
    let dir = tmpdir("sidecar_guard");
    {
        let mut ctx = matrix::toy_ctx(1, 0xC0FFEE).unwrap();
        let mut params = matrix::toy_params(0x1717);
        let mut method = make("lift");
        let cfg = TrainCfg {
            ckpt_every: 2,
            ckpt_dir: Some(dir.clone()),
            ..base_cfg(4)
        };
        train_with(
            &mut matrix::synth_step,
            &mut *method,
            &mut ctx,
            &mut params,
            &cfg,
            None,
        )
        .unwrap();
    }
    assert!(ckpt::latest_snapshot(&dir).unwrap().is_some());
    let before = std::fs::metadata(lift::ckpt::curve::curve_path(&dir)).unwrap().len();
    // same directory, fresh run (no --resume): must error, and must
    // leave the sidecar bytes untouched
    let mut ctx = matrix::toy_ctx(1, 0xC0FFEE).unwrap();
    let mut params = matrix::toy_params(0x1717);
    let mut method = make("lift");
    let cfg = TrainCfg {
        ckpt_every: 2,
        ckpt_dir: Some(dir.clone()),
        ..base_cfg(4)
    };
    let err = train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &cfg,
        None,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("ahead of this run's start"), "{msg}");
    let after = std::fs::metadata(lift::ckpt::curve::curve_path(&dir)).unwrap().len();
    assert_eq!(before, after, "the sidecar must not be truncated on refusal");
    // resuming from the newest snapshot is the sanctioned way in
    let snap = ckpt::latest_snapshot(&dir).unwrap().unwrap();
    let mut method2 = make("lift");
    train_with(
        &mut matrix::synth_step,
        &mut *method2,
        &mut ctx,
        &mut params,
        &cfg,
        Some(&snap),
    )
    .unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- corruption / compatibility ----------------------------------------

/// Write one real trainer snapshot to tamper with.
fn sample_snapshot(dir: &Path) -> PathBuf {
    let mut ctx = matrix::toy_ctx(2, 0xC0FFEE).unwrap();
    let mut params = matrix::toy_params(0x1717);
    let mut method = make("lift");
    let cfg = TrainCfg {
        ckpt_every: 2,
        ckpt_dir: Some(dir.to_path_buf()),
        ..base_cfg(2)
    };
    train_with(
        &mut matrix::synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &cfg,
        None,
    )
    .unwrap();
    ckpt::latest_snapshot(dir).unwrap().unwrap()
}

#[test]
fn truncated_snapshot_is_rejected() {
    let dir = tmpdir("truncate");
    let path = sample_snapshot(&dir);
    let bytes = std::fs::read(&path).unwrap();
    for cut in [bytes.len() - 7, 20, 10] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = ckpt::load_trainer(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated") || msg.contains("CRC32"),
            "cut at {cut}: unexpected error: {msg}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn flipped_byte_is_rejected_by_crc() {
    let dir = tmpdir("bitflip");
    let path = sample_snapshot(&dir);
    let good = std::fs::read(&path).unwrap();
    // flip one byte inside each section's payload region (the tail of
    // the file is the last section's payload; byte 40 sits in the first)
    for pos in [40usize, good.len() / 2, good.len() - 1] {
        let mut bad = good.clone();
        bad[pos] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        let err = ckpt::load_trainer(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("CRC32") || msg.contains("section"),
            "flip at {pos}: unexpected error: {msg}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bumped_format_version_fails_loudly() {
    let dir = tmpdir("version");
    let path = sample_snapshot(&dir);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12].copy_from_slice(&(ckpt::FORMAT_VERSION + 1).to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let err = ckpt::load_trainer(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("format version") && msg.contains("refusing"),
        "unexpected error: {msg}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn snapshot_container_roundtrips_random_sections() {
    let dir = tmpdir("prop_container");
    let mut case = 0usize;
    check("snapshot_container_roundtrip", |rng| {
        case += 1;
        let n_sec = 1 + rng.below(4);
        let mut snap = Snapshot::new();
        for s in 0..n_sec {
            let len = rng.below(200); // includes empty payloads
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            snap.add(&format!("sec{s}"), payload);
        }
        let path = dir.join(format!("prop_{case}.snap"));
        snap.write_to(&path).map_err(|e| e.to_string())?;
        let back = Snapshot::read_from(&path).map_err(|e| e.to_string())?;
        ensure(back.sections == snap.sections, "sections drifted")
    });
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trainer_state_roundtrips_degenerate_shapes() {
    // randomized tensor shapes including m=1 / n=1 / scalar-ish tensors,
    // and a sparse state with an empty mask (the rank-0 / k=0 edge)
    let dir = tmpdir("prop_shapes");
    let mut case = 0usize;
    check("trainer_state_roundtrip", |rng| {
        case += 1;
        let mut params = vec![
            Tensor::randn(&[1, 1], 1.0, rng),
            Tensor::randn(&[1, 1 + rng.below(6)], 1.0, rng),
            Tensor::randn(&[1 + rng.below(6), 1], 1.0, rng),
        ];
        for _ in 0..rng.below(3) {
            let m = 1 + rng.below(5);
            let n = 1 + rng.below(5);
            params.push(Tensor::randn(&[m, n], 1.0, rng));
        }
        use lift::optim::{AdamCfg, SparseAdam};
        let mut e = lift::ckpt::codec::Enc::new();
        e.sparse_adam(&SparseAdam::new(vec![], AdamCfg::default())); // empty mask
        e.sparse_adam(&SparseAdam::new(vec![0], AdamCfg::default()));
        let method_state = e.into_bytes();
        let path = dir.join(format!("prop_{case}.snap"));
        // build the snapshot by hand (ckpt::save_trainer needs a Method;
        // here we exercise the params/meta sections with edge shapes)
        let mut meta = lift::ckpt::codec::Enc::new();
        meta.str("probe");
        meta.usize(rng.below(100));
        meta.u64(rng.next_u64());
        meta.u64(rng.next_u64());
        meta.f64(0.25); // seconds (the curve itself lives in the sidecar)
        meta.f32(1e-3); // cfg: lr
        meta.f32(0.03); // cfg: warmup_frac
        meta.usize(100); // cfg: steps
        let mut ps = lift::ckpt::codec::Enc::new();
        ps.usize(params.len());
        for t in &params {
            ps.tensor(t);
        }
        let mut snap = Snapshot::new();
        snap.add("meta", meta.into_bytes());
        snap.add("params", ps.into_bytes());
        snap.add("method", method_state.clone());
        snap.write_to(&path).map_err(|e| e.to_string())?;
        let st = ckpt::load_trainer(&path).map_err(|e| e.to_string())?;
        ensure(st.method_name == "probe", "name drifted")?;
        ensure(st.seconds == 0.25, "seconds drifted")?;
        ensure(st.cfg_steps == 100, "cfg steps drifted")?;
        ensure(st.params == params, "params drifted")?;
        ensure(st.method_state == method_state, "method bytes drifted")?;
        let mut d = lift::ckpt::codec::Dec::new(&st.method_state);
        let empty = d.sparse_adam().map_err(|e| e.to_string())?;
        ensure(empty.idx.is_empty() && empty.m.is_empty(), "empty mask drifted")
    });
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- scenario-matrix runner --------------------------------------------

fn toy_cells(dir_tag: &str) -> (PathBuf, Vec<CellSpec>) {
    let dir = tmpdir(dir_tag);
    let cells = matrix::expand_grid(
        "toy",
        &["weight_mag".to_string(), "random".to_string()],
        &[],
        &[2],
        &[1],
        4,
        2,
    );
    assert_eq!(cells.len(), 2);
    (dir, cells)
}

#[test]
fn matrix_skips_finished_cells_and_recomputes_deleted_ones() {
    let (dir, cells) = toy_cells("matrix_ledger");
    let count = AtomicUsize::new(0);
    let run = |spec: &CellSpec| {
        count.fetch_add(1, Ordering::SeqCst);
        matrix::run_toy_cell(spec, &dir, 0, 0, 1)
    };
    // first run executes everything
    let r1 = matrix::run_matrix(&dir, &cells, 2, &run).unwrap();
    assert_eq!(r1.ran.len(), 2, "failed: {:?}", r1.failed);
    assert!(r1.skipped.is_empty() && r1.failed.is_empty());
    assert_eq!(count.load(Ordering::SeqCst), 2);
    // rerun skips everything
    let r2 = matrix::run_matrix(&dir, &cells, 2, &run).unwrap();
    assert!(r2.ran.is_empty() && r2.failed.is_empty());
    assert_eq!(r2.skipped.len(), 2);
    assert_eq!(count.load(Ordering::SeqCst), 2, "skipped cells must not execute");
    // deleting one outcome recomputes exactly that cell
    std::fs::remove_file(matrix::outcome_path(&dir, &cells[1].id())).unwrap();
    let r3 = matrix::run_matrix(&dir, &cells, 2, &run).unwrap();
    assert_eq!(r3.ran, vec![cells[1].id()]);
    assert_eq!(r3.skipped, vec![cells[0].id()]);
    assert_eq!(count.load(Ordering::SeqCst), 3);
    // a corrupted outcome counts as unfinished and is recomputed
    std::fs::write(matrix::outcome_path(&dir, &cells[0].id()), "{not json").unwrap();
    let r4 = matrix::run_matrix(&dir, &cells, 2, &run).unwrap();
    assert_eq!(r4.ran, vec![cells[0].id()]);
    assert_eq!(count.load(Ordering::SeqCst), 4);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn matrix_collects_failures_without_aborting_the_campaign() {
    let (dir, cells) = toy_cells("matrix_failures");
    let run = |spec: &CellSpec| {
        if spec.method == "random" {
            anyhow::bail!("synthetic cell failure");
        }
        matrix::run_toy_cell(spec, &dir, 0, 0, 1)
    };
    let r = matrix::run_matrix(&dir, &cells, 2, run).unwrap();
    assert_eq!(r.ran.len(), 1);
    assert_eq!(r.failed.len(), 1);
    assert!(r.failed[0].0.contains("random"));
    assert!(r.failed[0].1.contains("synthetic cell failure"));
    // the failed cell left no outcome, so a rerun retries only it
    let r2 = matrix::run_matrix(&dir, &cells, 2, |spec| {
        matrix::run_toy_cell(spec, &dir, 0, 0, 1)
    })
    .unwrap();
    assert_eq!(r2.ran.len(), 1);
    assert_eq!(r2.skipped.len(), 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn interrupted_toy_cell_resumes_from_its_checkpoint() {
    let spec = CellSpec {
        preset: "toy".into(),
        method: "lift".into(),
        suite: "arith".into(),
        rank: 2,
        seed: 1,
        steps: 4,
        interval: 2,
        qscan: false,
    };
    // straight run in its own directory
    let dir_straight = tmpdir("cell_straight");
    let straight = matrix::run_toy_cell(&spec, &dir_straight, 0, 0, 1).unwrap();
    // "crashed" run: the cell's own config, interrupted after 2 of 4
    // steps (snapshot at 2 already on disk); rerunning the cell must
    // pick the snapshot up instead of restarting
    let dir_crash = tmpdir("cell_crash");
    let full_ckpt = matrix::cell_ckpt_dir(&dir_crash, &spec.id());
    {
        let mut ctx = matrix::toy_ctx(1, 0xC311 ^ spec.seed).unwrap();
        let mut params = matrix::toy_params(0x1717 ^ spec.seed);
        let mut method = spec.method_with_lra(2).unwrap();
        let cfg = TrainCfg {
            steps: spec.steps,
            lr: 1e-3,
            warmup_frac: 0.03,
            log_every: 0,
            seed: spec.seed,
            ckpt_every: 2,
            ckpt_dir: Some(full_ckpt.clone()),
            ckpt_keep: 0,
        };
        let mut served = 0usize;
        let mut crashing = |params: &[Tensor], rng: &mut Rng| {
            if served == 2 {
                anyhow::bail!("simulated crash");
            }
            served += 1;
            matrix::synth_step(params, rng)
        };
        train_with(&mut crashing, &mut *method, &mut ctx, &mut params, &cfg, None)
            .unwrap_err();
    }
    assert!(ckpt::latest_snapshot(&full_ckpt).unwrap().is_some());
    let resumed = matrix::run_toy_cell(&spec, &dir_crash, 2, 0, 1).unwrap();
    assert_eq!(
        resumed.tail_loss.to_bits(),
        straight.tail_loss.to_bits(),
        "resumed cell diverged: {} vs {}",
        resumed.tail_loss,
        straight.tail_loss
    );
    assert_eq!(resumed.trainable, straight.trainable);
    assert_eq!(resumed.opt_bytes, straight.opt_bytes);
    std::fs::remove_dir_all(&dir_straight).unwrap();
    std::fs::remove_dir_all(&dir_crash).unwrap();
}

//! Mask-engine contract tests (ISSUE 1):
//!
//! * parallel-vs-sequential determinism — for every `Selector` and every
//!   `RankStrategy`, masks from the layer-parallel engine with 1 worker
//!   and with N workers are bit-identical under a fixed seed;
//! * randomized-vs-exact parity — the mask built from `svd_lowrank`
//!   (randomized subspace iteration) overlaps the exact Jacobi-SVD
//!   oracle's mask by at least [`PARITY_MIN_OVERLAP`] on synthetic
//!   low-rank-plus-noise matrices.
//!
//! These run without AOT artifacts: the whole pipeline goes through the
//! XlaBuilder toolkit.

use std::sync::Arc;

use lift::lift::engine::MaskEngine;
use lift::lift::{
    budget_for, mask_overlap, principal_indices, LiftCfg, MaskRequest, RankStrategy, Selector,
};
use lift::runtime::Linalg;
use lift::tensor::Tensor;
use lift::util::rng::Rng;

/// Documented parity threshold: on rank-4 matrices with 5% additive
/// noise, the randomized rank reduction (2 power iterations, 8
/// oversampling columns — the `LiftCfg` defaults) recovers the principal
/// subspace almost exactly, so the two masks agree on well over 85% of
/// entries; the bound leaves slack for tie-breaks near the top-k
/// threshold. Tightening the noise raises the overlap toward 1.0.
const PARITY_MIN_OVERLAP: f64 = 0.85;

fn linalg() -> Arc<Linalg> {
    Arc::new(Linalg::new(&xla::PjRtClient::cpu().unwrap()))
}

struct Fixture {
    ws: Vec<Tensor>,
    gs: Vec<Tensor>,
    scores: Vec<Vec<f32>>,
    ks: Vec<usize>,
}

impl Fixture {
    fn new(seed: u64, rank_equiv: usize) -> Fixture {
        let mut rng = Rng::new(seed);
        let shapes = [(24usize, 16usize), (16, 32), (20, 20), (12, 40), (28, 12)];
        let ws: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let gs: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let scores: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&(m, n)| rng.normal_vec(m * n, 1.0))
            .collect();
        let ks = shapes
            .iter()
            .map(|&(m, n)| budget_for(m, n, rank_equiv))
            .collect();
        Fixture { ws, gs, scores, ks }
    }

    fn requests(&self) -> Vec<MaskRequest<'_>> {
        self.ws
            .iter()
            .enumerate()
            .map(|(i, w)| MaskRequest {
                tag: i as u64,
                w,
                grad: Some(&self.gs[i]),
                score: Some(&self.scores[i]),
                k: self.ks[i],
            })
            .collect()
    }
}

fn assert_seq_eq_par(sel: Selector, cfg: &LiftCfg, fix: &Fixture, seed: u64, label: &str) {
    let la = linalg();
    let seq = MaskEngine::with_workers(la.clone(), 1)
        .select_all(sel, cfg, &fix.requests(), seed)
        .unwrap();
    let par = MaskEngine::with_workers(la, 4)
        .select_all(sel, cfg, &fix.requests(), seed)
        .unwrap();
    assert_eq!(seq, par, "{label}: parallel masks != sequential masks");
    for (mi, mask) in seq.iter().enumerate() {
        assert_eq!(mask.len(), fix.ks[mi], "{label}: matrix {mi} budget");
        assert!(
            mask.windows(2).all(|w| w[0] < w[1]),
            "{label}: matrix {mi} not sorted/unique"
        );
    }
}

#[test]
fn every_selector_is_worker_count_invariant() {
    let fix = Fixture::new(41, 4);
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    for sel in [
        Selector::Lift,
        Selector::WeightMag,
        Selector::GradMag,
        Selector::Movement,
        Selector::Random,
    ] {
        assert_seq_eq_par(sel, &cfg, &fix, 0xD5, &format!("{sel:?}"));
    }
}

#[test]
fn every_rank_strategy_is_worker_count_invariant() {
    let fix = Fixture::new(43, 4);
    for strategy in [
        RankStrategy::Largest,
        RankStrategy::Smallest,
        RankStrategy::Random,
        RankStrategy::Hybrid,
    ] {
        // ablation strategies route through the exact host SVD
        let cfg = LiftCfg {
            rank: 4,
            exact: true,
            strategy,
            ..Default::default()
        };
        assert_seq_eq_par(Selector::Lift, &cfg, &fix, 0xA7, &format!("{strategy:?}"));
    }
    // and the randomized Largest path (the production default)
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    assert_seq_eq_par(Selector::Lift, &cfg, &fix, 0xA7, "randomized Largest");
}

#[test]
fn same_seed_same_masks_different_seed_different_masks() {
    let fix = Fixture::new(47, 4);
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    let la = linalg();
    let eng = MaskEngine::with_workers(la, 3);
    let a = eng.select_all(Selector::Lift, &cfg, &fix.requests(), 7).unwrap();
    let b = eng.select_all(Selector::Lift, &cfg, &fix.requests(), 7).unwrap();
    assert_eq!(a, b, "same seed must reproduce masks exactly");
    // a different refresh seed redraws the subspace-iteration test
    // matrices; for Random selection it redraws everything
    let c = eng
        .select_all(Selector::Random, &cfg, &fix.requests(), 7)
        .unwrap();
    let d = eng
        .select_all(Selector::Random, &cfg, &fix.requests(), 8)
        .unwrap();
    assert_ne!(c, d, "different seeds should differ for Random selection");
}

#[test]
fn empty_and_oversubscribed_batches() {
    let la = linalg();
    let cfg = LiftCfg::default();
    let eng = MaskEngine::with_workers(la, 16);
    let empty: Vec<MaskRequest> = Vec::new();
    assert!(eng
        .select_all(Selector::WeightMag, &cfg, &empty, 1)
        .unwrap()
        .is_empty());
    // more workers than requests
    let fix = Fixture::new(53, 2);
    let masks = eng
        .select_all(Selector::WeightMag, &cfg, &fix.requests()[..2], 1)
        .unwrap();
    assert_eq!(masks.len(), 2);
}

#[test]
fn randomized_matches_exact_oracle_above_threshold() {
    let la = linalg();
    for seed in 1u64..=5 {
        let mut rng = Rng::new(seed);
        let (m, n, r) = (48usize, 40usize, 4usize);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut w = u.matmul(&v);
        w.add_scaled(&Tensor::randn(&[m, n], 1.0, &mut rng), 0.05);
        let k = budget_for(m, n, 8);
        let fast_cfg = LiftCfg {
            rank: r,
            ..Default::default()
        };
        let exact_cfg = LiftCfg {
            rank: r,
            exact: true,
            ..Default::default()
        };
        let fast = principal_indices(&la, &w, k, &fast_cfg, &mut rng).unwrap();
        let exact = principal_indices(&la, &w, k, &exact_cfg, &mut rng).unwrap();
        let ov = mask_overlap(&fast, &exact);
        assert!(
            ov >= PARITY_MIN_OVERLAP,
            "seed {seed}: randomized-vs-exact overlap {ov:.3} < {PARITY_MIN_OVERLAP}"
        );
    }
}

#[test]
fn speedup_measurement_reports_a_row() {
    let la = linalg();
    let shapes = [(16usize, 12usize), (12, 16), (16, 16), (20, 12)];
    let row = lift::exp::harness::measure_mask_refresh(&la, &shapes, 4, 4, 2, 1).unwrap();
    assert!(row.seq_s > 0.0 && row.par_s > 0.0);
    assert_eq!(row.matrices, shapes.len());
    assert!(row.row().contains("mask_refresh"), "row: {}", row.row());
}

//! Engine contract tests (ISSUE 1 + ISSUE 2):
//!
//! * parallel-vs-sequential determinism — for every `Selector` and every
//!   `RankStrategy` (including the exact top-r subspace path), masks
//!   from the layer-parallel engine with 1 worker and with N workers are
//!   bit-identical under a fixed seed;
//! * cross-worker trainer determinism — K trainer steps
//!   (`refresh_all` + `step_all`) with 1 worker and with N workers
//!   produce bit-identical weights and optimizer moments for every
//!   `Method`, and the batched path matches direct `step()` drivers;
//! * refresh/step ordering — a mid-run mask swap migrates Adam moments
//!   before the batched step reads them;
//! * randomized-vs-exact parity — the mask built from `svd_lowrank`
//!   (randomized subspace iteration) overlaps the exact oracle's mask by
//!   at least [`PARITY_MIN_OVERLAP`] on synthetic low-rank-plus-noise
//!   matrices.
//!
//! These run without AOT artifacts: the whole pipeline goes through the
//! XlaBuilder toolkit.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

use lift::lift::engine::MaskEngine;
use lift::lift::{
    budget_for, mask_overlap, principal_indices, LiftCfg, MaskRequest, RankStrategy, Selector,
};
use lift::methods::sparse_ft::SparseFt;
use lift::methods::{digest_words, make_method, Ctx, Method, Scope};
use lift::model;
use lift::optim::AdamCfg;
use lift::runtime::manifest::{ParamInfo, PresetInfo};
use lift::runtime::Linalg;
use lift::tensor::Tensor;
use lift::util::rng::Rng;

/// Documented parity threshold: on rank-4 matrices with 5% additive
/// noise, the randomized rank reduction (2 power iterations, 8
/// oversampling columns — the `LiftCfg` defaults) recovers the principal
/// subspace almost exactly, so the two masks agree on well over 85% of
/// entries; the bound leaves slack for tie-breaks near the top-k
/// threshold. Tightening the noise raises the overlap toward 1.0.
const PARITY_MIN_OVERLAP: f64 = 0.85;

fn linalg() -> Arc<Linalg> {
    Arc::new(Linalg::new(&xla::PjRtClient::cpu().unwrap()))
}

struct Fixture {
    ws: Vec<Tensor>,
    gs: Vec<Tensor>,
    scores: Vec<Vec<f32>>,
    ks: Vec<usize>,
}

impl Fixture {
    fn new(seed: u64, rank_equiv: usize) -> Fixture {
        let mut rng = Rng::new(seed);
        let shapes = [(24usize, 16usize), (16, 32), (20, 20), (12, 40), (28, 12)];
        let ws: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let gs: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let scores: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&(m, n)| rng.normal_vec(m * n, 1.0))
            .collect();
        let ks = shapes
            .iter()
            .map(|&(m, n)| budget_for(m, n, rank_equiv))
            .collect();
        Fixture { ws, gs, scores, ks }
    }

    fn requests(&self) -> Vec<MaskRequest<'_>> {
        self.ws
            .iter()
            .enumerate()
            .map(|(i, w)| MaskRequest {
                tag: i as u64,
                w,
                grad: Some(&self.gs[i]),
                score: Some(&self.scores[i]),
                k: self.ks[i],
            })
            .collect()
    }
}

fn assert_seq_eq_par(sel: Selector, cfg: &LiftCfg, fix: &Fixture, seed: u64, label: &str) {
    let la = linalg();
    let seq = MaskEngine::with_workers(la.clone(), 1)
        .select_all(sel, cfg, &fix.requests(), seed)
        .unwrap();
    let par = MaskEngine::with_workers(la, 4)
        .select_all(sel, cfg, &fix.requests(), seed)
        .unwrap();
    assert_eq!(seq, par, "{label}: parallel masks != sequential masks");
    for (mi, mask) in seq.iter().enumerate() {
        assert_eq!(mask.len(), fix.ks[mi], "{label}: matrix {mi} budget");
        assert!(
            mask.windows(2).all(|w| w[0] < w[1]),
            "{label}: matrix {mi} not sorted/unique"
        );
    }
}

#[test]
fn every_selector_is_worker_count_invariant() {
    let fix = Fixture::new(41, 4);
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    for sel in [
        Selector::Lift,
        Selector::WeightMag,
        Selector::GradMag,
        Selector::Movement,
        Selector::Random,
    ] {
        assert_seq_eq_par(sel, &cfg, &fix, 0xD5, &format!("{sel:?}"));
    }
}

#[test]
fn every_rank_strategy_is_worker_count_invariant() {
    let fix = Fixture::new(43, 4);
    for strategy in [
        RankStrategy::Largest,
        RankStrategy::Smallest,
        RankStrategy::Random,
        RankStrategy::Hybrid,
    ] {
        // ablation strategies route through the exact host SVD
        let cfg = LiftCfg {
            rank: 4,
            exact: true,
            strategy,
            ..Default::default()
        };
        assert_seq_eq_par(Selector::Lift, &cfg, &fix, 0xA7, &format!("{strategy:?}"));
    }
    // and the randomized Largest path (the production default)
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    assert_seq_eq_par(Selector::Lift, &cfg, &fix, 0xA7, "randomized Largest");
}

#[test]
fn same_seed_same_masks_different_seed_different_masks() {
    let fix = Fixture::new(47, 4);
    let cfg = LiftCfg {
        rank: 4,
        ..Default::default()
    };
    let la = linalg();
    let eng = MaskEngine::with_workers(la, 3);
    let a = eng.select_all(Selector::Lift, &cfg, &fix.requests(), 7).unwrap();
    let b = eng.select_all(Selector::Lift, &cfg, &fix.requests(), 7).unwrap();
    assert_eq!(a, b, "same seed must reproduce masks exactly");
    // a different refresh seed redraws the subspace-iteration test
    // matrices; for Random selection it redraws everything
    let c = eng
        .select_all(Selector::Random, &cfg, &fix.requests(), 7)
        .unwrap();
    let d = eng
        .select_all(Selector::Random, &cfg, &fix.requests(), 8)
        .unwrap();
    assert_ne!(c, d, "different seeds should differ for Random selection");
}

#[test]
fn empty_and_oversubscribed_batches() {
    let la = linalg();
    let cfg = LiftCfg::default();
    let eng = MaskEngine::with_workers(la, 16);
    let empty: Vec<MaskRequest> = Vec::new();
    assert!(eng
        .select_all(Selector::WeightMag, &cfg, &empty, 1)
        .unwrap()
        .is_empty());
    // more workers than requests
    let fix = Fixture::new(53, 2);
    let masks = eng
        .select_all(Selector::WeightMag, &cfg, &fix.requests()[..2], 1)
        .unwrap();
    assert_eq!(masks.len(), 2);
}

#[test]
fn randomized_matches_exact_oracle_above_threshold() {
    let la = linalg();
    for seed in 1u64..=5 {
        let mut rng = Rng::new(seed);
        let (m, n, r) = (48usize, 40usize, 4usize);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut w = u.matmul(&v);
        w.add_scaled(&Tensor::randn(&[m, n], 1.0, &mut rng), 0.05);
        let k = budget_for(m, n, 8);
        let fast_cfg = LiftCfg {
            rank: r,
            ..Default::default()
        };
        let exact_cfg = LiftCfg {
            rank: r,
            exact: true,
            ..Default::default()
        };
        let fast = principal_indices(&la, &w, k, &fast_cfg, &mut rng).unwrap();
        let exact = principal_indices(&la, &w, k, &exact_cfg, &mut rng).unwrap();
        let ov = mask_overlap(&fast, &exact);
        assert!(
            ov >= PARITY_MIN_OVERLAP,
            "seed {seed}: randomized-vs-exact overlap {ov:.3} < {PARITY_MIN_OVERLAP}"
        );
    }
}

#[test]
fn speedup_measurement_reports_a_row() {
    let la = linalg();
    let shapes = [(16usize, 12usize), (12, 16), (16, 16), (20, 12)];
    let row = lift::exp::harness::measure_mask_refresh(&la, &shapes, 4, 4, 2, 1).unwrap();
    assert!(row.seq_s > 0.0 && row.par_s > 0.0);
    assert_eq!(row.matrices, shapes.len());
    assert!(row.row().contains("mask_refresh"), "row: {}", row.row());
}

#[test]
fn warm_refresh_measurement_reports_a_row() {
    // min side 32 > 2(4 + oversample), so the subspace path (and its
    // carrier) actually engages in the measured refreshes
    let shapes = [(40usize, 32usize), (32, 48)];
    let row = lift::exp::harness::measure_warm_refresh(&shapes, 4, 1).unwrap();
    assert!(row.seq_s > 0.0 && row.par_s > 0.0);
    assert_eq!(row.matrices, shapes.len());
    assert!(row.row().contains("warm_refresh"), "row: {}", row.row());
}

#[test]
fn step_all_speedup_measurement_reports_a_row() {
    let shapes = [(16usize, 12usize), (12, 16), (16, 16), (20, 12)];
    let row = lift::exp::harness::measure_step_all(&shapes, 4, 2, 1, 2).unwrap();
    assert!(row.seq_s > 0.0 && row.par_s > 0.0);
    assert_eq!(row.matrices, shapes.len());
    assert!(row.row().contains("step_all"), "row: {}", row.row());
}

#[test]
fn exact_topr_path_is_worker_count_invariant() {
    // matrices large enough that the exact path's top-r subspace
    // iteration engages (2(rank + oversample) < min(m, n)); the small
    // fixture in every_rank_strategy_is_worker_count_invariant covers
    // the full-Jacobi fallback
    let mut rng = Rng::new(61);
    let shapes = [(64usize, 80usize), (96, 64), (72, 72)];
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
        .collect();
    let reqs: Vec<MaskRequest> = ws
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let (m, n) = w.dims2();
            MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k: budget_for(m, n, 4),
            }
        })
        .collect();
    let cfg = LiftCfg {
        rank: 4,
        exact: true,
        ..Default::default()
    };
    let la = linalg();
    let seq = MaskEngine::with_workers(la.clone(), 1)
        .select_all(Selector::Lift, &cfg, &reqs, 0xE5)
        .unwrap();
    let par = MaskEngine::with_workers(la, 4)
        .select_all(Selector::Lift, &cfg, &reqs, 0xE5)
        .unwrap();
    assert_eq!(seq, par, "exact top-r masks diverged across worker counts");
    for (mi, mask) in seq.iter().enumerate() {
        assert!(!mask.is_empty(), "matrix {mi} selected nothing");
    }
}

#[test]
fn warm_refresh_masks_and_carriers_are_worker_count_invariant() {
    // two consecutive refreshes of a drifting model through
    // select_all_warm: the second is warm-started from the first's
    // carriers. Masks AND carriers must be bit-identical at 1 and 4
    // workers — the carriers are checkpointed state, so worker-count
    // leakage here would break crash-resume determinism, not just perf.
    use lift::util::eigh::SubspaceWarm;
    let mut rng = Rng::new(67);
    let shapes = [(64usize, 80usize), (96, 64), (72, 72)];
    let cfg = LiftCfg {
        rank: 4,
        exact: true,
        ..Default::default()
    };
    let la = linalg();
    let run = |workers: usize, ws: &[Tensor], drifted: &[Tensor]| {
        let eng = MaskEngine::with_workers(la.clone(), workers);
        let reqs: Vec<MaskRequest> = ws
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let (m, n) = w.dims2();
                MaskRequest {
                    tag: i as u64,
                    w,
                    grad: None,
                    score: None,
                    k: budget_for(m, n, 4),
                }
            })
            .collect();
        let mut warms: Vec<Option<SubspaceWarm>> = (0..reqs.len()).map(|_| None).collect();
        let first = eng
            .select_all_warm(Selector::Lift, &cfg, &reqs, 0xF1, &mut warms)
            .unwrap();
        assert!(
            warms.iter().all(|w| w.is_some()),
            "subspace-path refreshes must emit carriers"
        );
        let dreqs: Vec<MaskRequest> = drifted
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let (m, n) = w.dims2();
                MaskRequest {
                    tag: i as u64,
                    w,
                    grad: None,
                    score: None,
                    k: budget_for(m, n, 4),
                }
            })
            .collect();
        let second = eng
            .select_all_warm(Selector::Lift, &cfg, &dreqs, 0xF2, &mut warms)
            .unwrap();
        (first, second, warms)
    };
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
        .collect();
    let drifted: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let mut d = w.clone();
            d.add_scaled(&Tensor::randn(&w.shape, 0.02, &mut rng), 1.0);
            d
        })
        .collect();
    let (f1, s1, c1) = run(1, &ws, &drifted);
    let (f4, s4, c4) = run(4, &ws, &drifted);
    assert_eq!(f1, f4, "cold masks diverged across worker counts");
    assert_eq!(s1, s4, "warm masks diverged across worker counts");
    assert_eq!(c1, c4, "warm carriers diverged across worker counts");
    // and a warm refresh selects what a cold one would: on a drifted
    // model the two factorizations agree to tolerance, so the masks
    // overlap near-perfectly (exact equality is tie-break luck)
    let eng = MaskEngine::with_workers(la, 2);
    let dreqs: Vec<MaskRequest> = drifted
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let (m, n) = w.dims2();
            MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k: budget_for(m, n, 4),
            }
        })
        .collect();
    let cold_second = eng
        .select_all_warm(
            Selector::Lift,
            &cfg,
            &dreqs,
            0xF2,
            &mut (0..dreqs.len()).map(|_| None).collect::<Vec<_>>(),
        )
        .unwrap();
    for (mi, (warm_mask, cold_mask)) in s1.iter().zip(&cold_second).enumerate() {
        let ov = mask_overlap(warm_mask, cold_mask);
        assert!(
            ov >= 0.97,
            "matrix {mi}: warm-refresh mask drifted from cold selection (overlap {ov:.4})"
        );
    }
}

#[test]
fn intra_matrix_parallel_gemm_keeps_masks_and_carriers_bit_identical() {
    // ISSUE 7: when the pool has more workers than requests, each
    // worker's scratch carries an intra-matrix budget and the exact
    // path's Gram/apply/RR products split row tiles across it. The big
    // matrix here pushes its Gram build past the gemm fan-out threshold
    // (160·161/2·520 ≈ 6.7M muladds > 2^22), so the 8-worker run (3
    // requests → intra budget 2) genuinely tiles while the 1-worker run
    // stays serial — and masks AND warm carriers must still match
    // bit-for-bit, carrier included because it is checkpointed state.
    use lift::util::eigh::SubspaceWarm;
    let mut rng = Rng::new(71);
    let shapes = [(520usize, 160usize), (64, 80), (72, 72)];
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
        .collect();
    let cfg = LiftCfg {
        rank: 4,
        exact: true,
        ..Default::default()
    };
    let la = linalg();
    let run = |workers: usize| {
        let eng = MaskEngine::with_workers(la.clone(), workers);
        let reqs: Vec<MaskRequest> = ws
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let (m, n) = w.dims2();
                MaskRequest {
                    tag: i as u64,
                    w,
                    grad: None,
                    score: None,
                    k: budget_for(m, n, 4),
                }
            })
            .collect();
        let mut warms: Vec<Option<SubspaceWarm>> = (0..reqs.len()).map(|_| None).collect();
        let masks = eng
            .select_all_warm(Selector::Lift, &cfg, &reqs, 0xF7, &mut warms)
            .unwrap();
        (masks, warms)
    };
    let (m1, c1) = run(1);
    let (m8, c8) = run(8);
    assert_eq!(m1, m8, "intra-matrix-parallel GEMM changed the masks");
    assert_eq!(c1, c8, "intra-matrix-parallel GEMM changed the warm carriers");
    assert!(
        c1.iter().all(|c| c.is_some()),
        "subspace path must emit carriers for every matrix"
    );
}

#[test]
fn nan_poisoned_matrix_survives_select_all_warm() {
    // ISSUE 10 NaN-torture: one matrix in the set has NaN weights (a
    // diverged layer). The engine must not panic, masks must stay
    // bit-identical across worker counts, every mask must still meet
    // its budget, and the loud NaN warning fires exactly once per run
    // (only the poisoned matrix trips it).
    use lift::lift::nan_warning_count;
    use lift::util::eigh::SubspaceWarm;
    let mut rng = Rng::new(83);
    let shapes = [(24usize, 16usize), (16, 32), (20, 20), (12, 40)];
    let mut ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
        .collect();
    // poison matrix 2 with a few NaN entries — the rank reduction
    // spreads them across the whole reduced matrix
    for &i in &[3usize, 77, 201] {
        ws[2].data[i] = f32::NAN;
    }
    let cfg = LiftCfg {
        rank: 4,
        exact: true,
        ..Default::default()
    };
    let la = linalg();
    let ks: Vec<usize> = shapes.iter().map(|&(m, n)| budget_for(m, n, 4)).collect();
    let run = |workers: usize| {
        let eng = MaskEngine::with_workers(la.clone(), workers);
        let reqs: Vec<MaskRequest> = ws
            .iter()
            .enumerate()
            .map(|(i, w)| MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k: ks[i],
            })
            .collect();
        let mut warms: Vec<Option<SubspaceWarm>> = (0..reqs.len()).map(|_| None).collect();
        let before = nan_warning_count();
        let masks = eng
            .select_all_warm(Selector::Lift, &cfg, &reqs, 0xA3, &mut warms)
            .unwrap();
        assert_eq!(
            nan_warning_count(),
            before + 1,
            "{workers}w: warning must fire exactly once (poisoned matrix only)"
        );
        masks
    };
    let m1 = run(1);
    let m4 = run(4);
    assert_eq!(m1, m4, "NaN-poisoned run diverged across worker counts");
    for (mi, mask) in m1.iter().enumerate() {
        assert_eq!(mask.len(), ks[mi], "matrix {mi} must still meet its budget");
        assert!(
            mask.windows(2).all(|w| w[0] < w[1]),
            "matrix {mi} not sorted/unique"
        );
    }
    // Under a forced quantized scan (LIFT_QSCAN=1 suite run) the NaNs
    // quantize to 0 inside the Gram, so only the poisoned *rows* of W'
    // come back NaN (via the final f64 apply) and the mask is
    // data-dependent — the loud-warning, budget, and worker-invariance
    // assertions above are the contract there.
    if lift::lift::qscan_forced() {
        return;
    }
    // the poisoned matrix's reduced form is all-NaN, so its mask is the
    // documented deterministic fallback: the first k indices
    let want: Vec<u32> = (0..ks[2] as u32).collect();
    assert_eq!(m1[2], want, "NaN-last policy pins the poisoned mask");
}

#[test]
fn qscan_masks_are_worker_count_invariant() {
    // the quantized scan is lossy vs f64 but still deterministic: int8
    // blocks quantize identically everywhere and the i32 accumulate is
    // exact, so 1-worker and 4-worker qscan masks must be bit-identical
    use lift::util::eigh::SubspaceWarm;
    let mut rng = Rng::new(89);
    let shapes = [(64usize, 80usize), (96, 64), (72, 72)];
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
        .collect();
    let cfg = LiftCfg {
        rank: 4,
        exact: true,
        qscan: true,
        ..Default::default()
    };
    let la = linalg();
    let run = |workers: usize| {
        let eng = MaskEngine::with_workers(la.clone(), workers);
        let reqs: Vec<MaskRequest> = ws
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let (m, n) = w.dims2();
                MaskRequest {
                    tag: i as u64,
                    w,
                    grad: None,
                    score: None,
                    k: budget_for(m, n, 4),
                }
            })
            .collect();
        let mut warms: Vec<Option<SubspaceWarm>> = (0..reqs.len()).map(|_| None).collect();
        let masks = eng
            .select_all_warm(Selector::Lift, &cfg, &reqs, 0xB5, &mut warms)
            .unwrap();
        (masks, warms)
    };
    let (m1, c1) = run(1);
    let (m4, c4) = run(4);
    assert_eq!(m1, m4, "qscan masks diverged across worker counts");
    assert_eq!(c1, c4, "qscan carriers diverged across worker counts");
    // and the lossy tier stays inside its documented selection contract
    let f64_cfg = LiftCfg {
        qscan: false,
        ..cfg
    };
    let eng = MaskEngine::with_workers(la, 2);
    let reqs: Vec<MaskRequest> = ws
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let (m, n) = w.dims2();
            MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k: budget_for(m, n, 4),
            }
        })
        .collect();
    let exact = eng
        .select_all(Selector::Lift, &f64_cfg, &reqs, 0xB5)
        .unwrap();
    for (mi, (q, e)) in m1.iter().zip(&exact).enumerate() {
        let ov = mask_overlap(q, e);
        assert!(
            ov >= lift::util::eigh::LIFT_QSCAN_TOL,
            "matrix {mi}: qscan overlap {ov:.4} below LIFT_QSCAN_TOL"
        );
    }
}

// ---- cross-worker trainer determinism: every Method, K steps ----

/// A 2-layer toy preset: enough matrices for real fan-out, plus an
/// embedding and a norm so dense methods cover non-matrix params too.
fn toy_preset() -> PresetInfo {
    let mut params = vec![ParamInfo {
        name: "embed".into(),
        shape: vec![32, 16],
    }];
    for l in 0..2 {
        for (kind, shape) in [
            ("wq", vec![16usize, 16usize]),
            ("wk", vec![16, 16]),
            ("wv", vec![16, 16]),
            ("wo", vec![16, 16]),
            ("wup", vec![16, 24]),
            ("wdown", vec![24, 16]),
        ] {
            params.push(ParamInfo {
                name: format!("l{l}.{kind}"),
                shape,
            });
        }
    }
    params.push(ParamInfo {
        name: "final_norm".into(),
        shape: vec![16],
    });
    PresetInfo {
        name: "toy".into(),
        d: 16,
        layers: 2,
        ffn: 24,
        vocab: 32,
        seq: 8,
        batch: 2,
        heads: 2,
        params,
        executables: BTreeMap::new(),
    }
}

fn toy_ctx(workers: usize) -> Ctx {
    Ctx {
        la: linalg(),
        preset: toy_preset(),
        rng: Rng::new(0xC0FFEE),
        adam: AdamCfg::default(),
        workers,
    }
}

fn toy_params() -> Vec<Tensor> {
    model::init_params(&toy_preset(), &mut Rng::new(0x1717))
}

fn weight_digest(params: &[Tensor]) -> u64 {
    digest_words(
        params
            .iter()
            .flat_map(|t| t.data.iter().map(|x| x.to_bits() as u64)),
    )
}

/// K synthetic trainer steps of `name`; grads are redrawn per step from
/// a fixed stream, so two runs differ only in worker count. `batched`
/// drives the trainer path (`refresh_all` + `step_all`); otherwise the
/// direct-`step` path old drivers use. Returns (weights, state) digests.
fn run_train(name: &str, workers: usize, steps: usize, batched: bool) -> (u64, u64) {
    let mut ctx = toy_ctx(workers);
    let mut params = toy_params();
    let mut method = make_method(
        name,
        4,
        LiftCfg {
            rank: 4,
            ..Default::default()
        },
        2, // refresh every 2 steps: migrations happen mid-run
        Scope::default(),
    )
    .unwrap();
    method.init(&mut ctx, &params).unwrap();
    let mut grng = Rng::new(0x9e37);
    for step in 0..steps {
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&p.shape, 0.1, &mut grng))
            .collect();
        if batched {
            method.refresh_all(&mut ctx, &params, &grads, step).unwrap();
            method
                .step_all(&mut ctx, &mut params, &grads, step, 1e-3)
                .unwrap();
        } else {
            method
                .step(&mut ctx, &mut params, &grads, step, 1e-3)
                .unwrap();
        }
    }
    (weight_digest(&params), method.state_digest())
}

/// Every method's names as `make_method` spells them.
const ALL_METHODS: [&str; 15] = [
    "lift",
    "lift_mlp",
    "lift_structured",
    "weight_mag",
    "grad_mag",
    "movement",
    "random",
    "sift",
    "spiel",
    "full",
    "lora",
    "pissa",
    "dora",
    "spectral",
    "s2ft",
];

#[test]
fn every_method_is_worker_count_invariant_over_a_run() {
    let init = weight_digest(&toy_params());
    for name in ALL_METHODS {
        let (w1, d1) = run_train(name, 1, 5, true);
        let (wn, dn) = run_train(name, 4, 5, true);
        assert_eq!(w1, wn, "{name}: weights diverged across worker counts");
        assert_eq!(d1, dn, "{name}: optimizer state diverged across worker counts");
        assert_ne!(w1, init, "{name}: nothing trained");
    }
}

#[test]
fn direct_step_matches_trainer_batched_path() {
    // direct `step()` drivers (no trainer refresh_all) keep the exact
    // semantics of the batched path: the idempotent maintenance guard
    // makes the two entry points converge on the same per-step work
    for name in ALL_METHODS {
        let (wb, db) = run_train(name, 4, 5, true);
        let (wd, dd) = run_train(name, 4, 5, false);
        assert_eq!(wb, wd, "{name}: direct step() weights diverged from step_all");
        assert_eq!(db, dd, "{name}: direct step() state diverged from step_all");
    }
}

#[test]
fn refresh_migrates_moments_before_batched_step() {
    // guards the refresh-then-step ordering in train::train: a refresh
    // that swaps mask indices must migrate Adam moments before the
    // batched step reads them
    let mut ctx = toy_ctx(3);
    let mut params = toy_params();
    let mut m = SparseFt::new(
        "probe",
        Selector::Random, // redraws the mask every refresh
        2,
        LiftCfg {
            rank: 2,
            ..Default::default()
        },
        2,
        Scope::default(),
    );
    m.init(&mut ctx, &params).unwrap();
    let pi = 1; // "l0.wq"
    let mut grng = Rng::new(3);
    let mut draw =
        |params: &[Tensor]| -> Vec<Tensor> {
            params
                .iter()
                .map(|p| Tensor::randn(&p.shape, 0.1, &mut grng))
                .collect()
        };
    for step in 0..2 {
        let grads = draw(&params);
        m.refresh_all(&mut ctx, &params, &grads, step).unwrap();
        m.step_all(&mut ctx, &mut params, &grads, step, 1e-2).unwrap();
    }
    let mask_before: Vec<u32> = m.mask_for(pi).unwrap().to_vec();
    let st_before = m.state_for(pi).unwrap().clone();
    assert_eq!(st_before.t, 2, "two steps taken");
    // step 2: the interval fires — mask swap + moment migration, then step
    let grads = draw(&params);
    let w_before = params[pi].clone();
    m.refresh_all(&mut ctx, &params, &grads, 2).unwrap();
    let mask_after: Vec<u32> = m.mask_for(pi).unwrap().to_vec();
    assert_ne!(mask_before, mask_after, "Random selector must swap the mask");
    let st_mid = m.state_for(pi).unwrap().clone();
    let old_pos: HashMap<u32, usize> = mask_before
        .iter()
        .enumerate()
        .map(|(j, &i)| (i, j))
        .collect();
    for (j, &i) in st_mid.idx.iter().enumerate() {
        match old_pos.get(&i) {
            Some(&oj) => {
                assert_eq!(st_mid.m[j], st_before.m[oj], "survivor {i} lost momentum");
                assert_eq!(st_mid.v[j], st_before.v[oj], "survivor {i} lost variance");
            }
            None => {
                assert_eq!(st_mid.m[j], 0.0, "newcomer {i} not cold");
                assert_eq!(st_mid.v[j], 0.0, "newcomer {i} not cold");
            }
        }
    }
    assert_eq!(st_mid.t, st_before.t, "refresh must not advance the timestep");
    // the batched step then moves exactly the new mask
    m.step_all(&mut ctx, &mut params, &grads, 2, 1e-2).unwrap();
    let new_set: HashSet<u32> = mask_after.iter().copied().collect();
    for i in 0..params[pi].len() {
        let moved = params[pi].data[i] != w_before.data[i];
        if new_set.contains(&(i as u32)) {
            assert!(moved, "new-mask entry {i} did not step");
        } else {
            assert!(
                !moved,
                "entry {i} outside the new mask moved — the step used a stale mask"
            );
        }
    }
}

#[test]
#[should_panic(expected = "gradient and parameter slices must be parallel")]
fn par_over_params_rejects_short_grads_with_its_own_message() {
    // a grads slice shorter than params must die on the descriptive
    // invariant assert, not on a bare index-out-of-bounds inside the
    // job-building loop
    let mut rng = Rng::new(11);
    let mut params: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[4, 4], 1.0, &mut rng)).collect();
    let grads: Vec<Tensor> = (0..2).map(|_| Tensor::randn(&[4, 4], 1.0, &mut rng)).collect();
    let states: Vec<(usize, usize)> = vec![(2, 0)];
    lift::lift::engine::par_over_params(states, &mut params, &grads, 1, |_, _, _| {});
}

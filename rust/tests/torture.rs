//! Armed fault-injection tests: the only place in the test suite that
//! arms `util::fault` plans. Arming is process-global, so every test
//! here holds a static mutex — they run serialized even under the
//! default parallel test runner, and a panicking test cannot leak its
//! plan into the next one (the gate disarms on entry).

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use lift::ckpt::{self, curve, writer::AsyncSnapshotWriter, Snapshot};
use lift::exp::torture::{run_torture, TortureCfg};
use lift::util::fault::{self, FaultPlan};

static GATE: Mutex<()> = Mutex::new(());

fn armed_test() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm(); // a prior panicking test must not leak its plan
    g
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lift_torture_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn snap_bytes(fill: u8) -> Vec<u8> {
    let mut s = Snapshot::new();
    s.add("meta", vec![fill; 32]);
    s.to_bytes()
}

// ---- curve sidecar prefix-rewrite under faults (satellite 4) -----------

#[test]
fn curve_prefix_rewrite_crash_preserves_the_old_copy() {
    let _g = armed_test();
    let dir = tmpdir("curve_crash");
    let mut w = curve::CurveWriter::open(&dir, &[]).unwrap();
    for i in 0..4 {
        w.append(i as f32, 0.5).unwrap();
    }
    w.flush().unwrap();
    drop(w);
    let before = std::fs::read(curve::curve_path(&dir)).unwrap();
    // the resume-install of a shorter prefix crashes just before its
    // rename: the only copy of the curve must survive byte-identically
    fault::arm(FaultPlan::parse("rename:crash-before@0", 0).unwrap());
    let err = curve::CurveWriter::open(&dir, &[(0.0, 0.5), (1.0, 0.5)]).unwrap_err();
    let stats = fault::disarm();
    assert_eq!(stats.injected, 1, "the planned crash must fire");
    assert!(
        format!("{err:#}").contains(fault::INJECTED_MARK),
        "crash must surface loudly: {err:#}"
    );
    assert_eq!(
        std::fs::read(curve::curve_path(&dir)).unwrap(),
        before,
        "pre-existing sidecar bytes must survive a crashed rewrite"
    );
    // disarmed retry lands the rewrite the crash interrupted
    let mut w = curve::CurveWriter::open(&dir, &[(0.0, 0.5), (1.0, 0.5)]).unwrap();
    w.flush().unwrap();
    drop(w);
    let (ls, _) = curve::read_curve(&dir, 2).unwrap();
    assert_eq!(ls, vec![0.0, 1.0]);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn curve_prefix_rewrite_short_write_preserves_the_old_copy() {
    let _g = armed_test();
    let dir = tmpdir("curve_short");
    let mut w = curve::CurveWriter::open(&dir, &[]).unwrap();
    for i in 0..3 {
        w.append(i as f32, 0.1).unwrap();
    }
    w.flush().unwrap();
    drop(w);
    let before = std::fs::read(curve::curve_path(&dir)).unwrap();
    // ENOSPC-style torn write into the temp: the committed sidecar must
    // be untouched, and only the temp may be torn
    fault::arm(FaultPlan::parse("write:short@0", 0).unwrap());
    let err = curve::CurveWriter::open(&dir, &[(0.0, 0.1)]).unwrap_err();
    let stats = fault::disarm();
    assert_eq!(stats.injected, 1);
    assert!(format!("{err:#}").contains(fault::INJECTED_MARK), "loud: {err:#}");
    assert_eq!(
        std::fs::read(curve::curve_path(&dir)).unwrap(),
        before,
        "short write must tear only the temp"
    );
    let tmp = curve::curve_path(&dir).with_extension("tmp");
    assert!(tmp.exists(), "the torn temp is the expected debris");
    // the disarmed retry rewrites the temp in full and commits over it
    let mut w = curve::CurveWriter::open(&dir, &[(0.0, 0.1)]).unwrap();
    w.flush().unwrap();
    drop(w);
    assert!(!tmp.exists(), "commit consumes the temp");
    let (ls, _) = curve::read_curve(&dir, 1).unwrap();
    assert_eq!(ls, vec![0.0]);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- async snapshot writer under faults (satellite 4) ------------------

#[test]
fn async_writer_crash_before_rename_keeps_the_prior_snapshot() {
    let _g = armed_test();
    let dir = tmpdir("writer_crash");
    std::fs::create_dir_all(&dir).unwrap();
    ckpt::write_atomic(&ckpt::snapshot_path(&dir, 1), &snap_bytes(1)).unwrap();
    let before = std::fs::read(ckpt::snapshot_path(&dir, 1)).unwrap();
    fault::arm(FaultPlan::parse("rename:crash-before@0", 0).unwrap());
    {
        let mut w = AsyncSnapshotWriter::new();
        // drop without finish(): the drain-on-drop path must absorb the
        // failed write without panicking (the trainer's error unwind)
        let _ = w.submit(ckpt::snapshot_path(&dir, 2), snap_bytes(2), 2);
    }
    let stats = fault::disarm();
    assert_eq!(stats.injected, 1, "the planned crash must fire");
    assert_eq!(
        std::fs::read(ckpt::snapshot_path(&dir, 1)).unwrap(),
        before,
        "prior snapshot must survive byte-identically"
    );
    assert!(
        !ckpt::snapshot_path(&dir, 2).exists(),
        "a crash before the rename must not commit the new snapshot"
    );
    assert_eq!(
        ckpt::latest_snapshot(&dir).unwrap().unwrap(),
        ckpt::snapshot_path(&dir, 1),
        "resume must still find the prior snapshot"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn async_writer_enospc_surfaces_loudly_and_prior_survives() {
    let _g = armed_test();
    let dir = tmpdir("writer_enospc");
    std::fs::create_dir_all(&dir).unwrap();
    ckpt::write_atomic(&ckpt::snapshot_path(&dir, 1), &snap_bytes(1)).unwrap();
    let before = std::fs::read(ckpt::snapshot_path(&dir, 1)).unwrap();
    fault::arm(FaultPlan::parse("write:enospc@0", 0).unwrap());
    let mut w = AsyncSnapshotWriter::new();
    let submitted = w.submit(ckpt::snapshot_path(&dir, 2), snap_bytes(2), 2);
    let finished = submitted.and_then(|_| w.finish().map(|_| ()));
    let stats = fault::disarm();
    assert_eq!(stats.injected, 1);
    let msg = format!("{:#}", finished.unwrap_err());
    assert!(
        msg.contains(fault::INJECTED_MARK) && msg.contains("enospc"),
        "ENOSPC must surface loudly by name: {msg}"
    );
    assert_eq!(std::fs::read(ckpt::snapshot_path(&dir, 1)).unwrap(), before);
    assert!(!ckpt::snapshot_path(&dir, 2).exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn transient_faults_are_retried_to_success() {
    let _g = armed_test();
    let dir = tmpdir("transient");
    std::fs::create_dir_all(&dir).unwrap();
    // EINTR on the temp write AND on the rename: both are transient, so
    // the op-level retry loop must land the commit with no caller-visible
    // error at all
    fault::arm(FaultPlan::parse("write:eintr@0,rename:eintr@0", 0).unwrap());
    ckpt::write_atomic(&ckpt::snapshot_path(&dir, 1), &snap_bytes(9)).unwrap();
    let stats = fault::disarm();
    assert_eq!(stats.injected, 2);
    assert_eq!(stats.retried, 2, "both EINTRs must be absorbed by retries");
    let snap = Snapshot::read_from(&ckpt::snapshot_path(&dir, 1)).unwrap();
    assert_eq!(snap.get("meta").unwrap()[0], 9, "committed bytes intact after retries");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- end-to-end torture runs -------------------------------------------

fn assert_no_tmp_debris(dir: &Path) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        if p.is_dir() {
            assert_no_tmp_debris(&p);
        } else {
            assert_ne!(
                p.extension().and_then(|x| x.to_str()),
                Some("tmp"),
                "torn temp survived the sweep: {}",
                p.display()
            );
        }
    }
}

#[test]
fn torture_schedules_recover_and_reports_are_deterministic() {
    let _g = armed_test();
    let out_a = tmpdir("e2e_a");
    let cfg = TortureCfg {
        schedules: 2,
        seed: 7,
        out: out_a.clone(),
        faults: 2,
        horizon: 24,
    };
    let r1 = run_torture(&cfg).unwrap();
    assert!(r1.failed.is_empty(), "schedules must recover:\n{}", r1.text);
    assert_no_tmp_debris(&out_a);
    assert_eq!(
        std::fs::read_to_string(out_a.join("torture_report.txt")).unwrap(),
        r1.text,
        "the persisted report is the returned report"
    );
    let out_b = tmpdir("e2e_b");
    let r2 = run_torture(&TortureCfg { out: out_b.clone(), ..cfg }).unwrap();
    assert_eq!(r1.text, r2.text, "same seed must produce a byte-identical report");
    let _ = std::fs::remove_dir_all(&out_a);
    let _ = std::fs::remove_dir_all(&out_b);
}

#[test]
fn torture_refuses_to_start_over_an_armed_plan() {
    let _g = armed_test();
    let out = tmpdir("armed_refusal");
    fault::arm(FaultPlan::parse("read:eio@0", 0).unwrap());
    let err = run_torture(&TortureCfg {
        schedules: 1,
        seed: 1,
        out: out.clone(),
        faults: 1,
        horizon: 8,
    })
    .unwrap_err();
    fault::disarm();
    assert!(
        format!("{err:#}").contains("already armed"),
        "must refuse, not silently disarm: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&out);
}

//! Hand-computed oracles for the pure eval kernels (ISSUE 5): the
//! metric arithmetic behind `train::eval::{accuracy, perplexity,
//! fact_recall, pass_at_k}` and the retention pass (`exp::retention`),
//! asserted on tiny fixtures worked out by hand — including the
//! empty-sample and all-wrong edge cases that previously had no
//! coverage. No AOT artifacts, no model execution: the executable-
//! driven wrappers feed these exact kernels.

use lift::data::tasks::Sample;
use lift::exp::retention::{retention_ratio, toy_retention};
use lift::tensor::Tensor;
use lift::train::eval::{
    accuracy_from_counts, exact_match_counts, pass_at_k_with, ppl_from_total_nll,
    recall_from_probs,
};

// ---- exact match (accuracy) --------------------------------------------

#[test]
fn exact_match_counts_hand_fixtures() {
    let seq = 4;
    // row 0: answer span at positions 2..4, both predicted right -> correct
    // row 1: answer at 1..3, second answer position wrong -> scored, wrong
    // row 2: no masked positions (padding row) -> not scored at all
    let targets = vec![
        9, 9, 5, 6, //
        9, 7, 8, 9, //
        0, 0, 0, 0,
    ];
    let preds = vec![
        1, 2, 5, 6, // prompt positions differ, answer positions match
        9, 7, 3, 9, // masked pos 1 matches, masked pos 2 wrong
        1, 1, 1, 1,
    ];
    let mask = vec![
        0.0, 0.0, 1.0, 1.0, //
        0.0, 1.0, 1.0, 0.0, //
        0.0, 0.0, 0.0, 0.0,
    ];
    assert_eq!(exact_match_counts(&preds, &targets, &mask, 3, seq), (1, 2));
    // all-wrong predictions: every scored row misses
    let all_wrong = vec![-1; 12];
    assert_eq!(exact_match_counts(&all_wrong, &targets, &mask, 3, seq), (0, 2));
    // empty batch: zero rows, zero scored
    assert_eq!(exact_match_counts(&[], &[], &[], 0, seq), (0, 0));
    // one flipped PROMPT position must not affect the row (mask gates it)
    let mut prompt_flipped = preds.clone();
    prompt_flipped[0] = -7;
    assert_eq!(exact_match_counts(&prompt_flipped, &targets, &mask, 3, seq), (1, 2));
}

#[test]
fn accuracy_from_counts_hand_fixtures() {
    assert_eq!(accuracy_from_counts(1, 2), 50.0);
    assert_eq!(accuracy_from_counts(3, 4), 75.0);
    // zero scored rows: 0.0, not a division panic or NaN
    assert_eq!(accuracy_from_counts(0, 0), 0.0);
    // all-wrong
    assert_eq!(accuracy_from_counts(0, 5), 0.0);
    // all-right
    assert_eq!(accuracy_from_counts(5, 5), 100.0);
}

// ---- perplexity ---------------------------------------------------------

#[test]
fn ppl_from_total_nll_hand_fixtures() {
    // two batches with mean NLL ln(4) -> perplexity exactly 4
    let total = 2.0 * 4.0f64.ln();
    assert!((ppl_from_total_nll(total, 2) - 4.0).abs() < 1e-12);
    // one batch at ln(2) -> 2
    assert!((ppl_from_total_nll(2.0f64.ln(), 1) - 2.0).abs() < 1e-12);
    // zero batches: no evidence -> 1.0 (finite for the ledger), not NaN
    assert_eq!(ppl_from_total_nll(0.0, 0), 1.0);
    // zero loss -> the floor perplexity of 1
    assert_eq!(ppl_from_total_nll(0.0, 3), 1.0);
}

// ---- fact recall --------------------------------------------------------

#[test]
fn recall_from_probs_hand_fixtures() {
    assert_eq!(recall_from_probs(&[0.25, 0.75]), 0.5);
    assert_eq!(recall_from_probs(&[1.0]), 1.0);
    // zero probes: nothing recalled, not a division panic
    assert_eq!(recall_from_probs(&[]), 0.0);
    // all-wrong model: zero mass on every ground truth
    assert_eq!(recall_from_probs(&[0.0, 0.0, 0.0]), 0.0);
}

#[test]
fn retention_ratio_hand_fixtures() {
    // base recall 0.5, after 0.4 -> 80% retained
    assert_eq!(retention_ratio(0.5, 0.4), Some(0.8));
    // nothing forgotten, even improved
    assert_eq!(retention_ratio(0.4, 0.5), Some(1.25));
    // an unpretrained base (recall ~ 0) has nothing to forget
    assert_eq!(retention_ratio(0.0, 0.3), None);
    assert_eq!(retention_ratio(1e-12, 0.3), None);
}

// ---- pass@k -------------------------------------------------------------

fn sample(prompt: &[i32], answer: &[i32]) -> Sample {
    let mut tokens = prompt.to_vec();
    let answer_start = tokens.len();
    tokens.extend_from_slice(answer);
    Sample {
        tokens,
        answer_start,
        answer_len: answer.len(),
    }
}

#[test]
fn pass_at_k_with_scripted_sampler() {
    let s1 = sample(&[1, 2], &[7, 8]);
    let s2 = sample(&[3], &[9]);
    let samples = vec![s1, s2];
    // s1 answers correctly only on its 3rd attempt; s2 never
    let mut temps: Vec<f32> = Vec::new();
    let mut attempts = std::collections::HashMap::<Vec<i32>, usize>::new();
    let mut sampler = |s: &Sample, temp: f32| -> anyhow::Result<Vec<i32>> {
        temps.push(temp);
        let t = attempts.entry(s.prompt().to_vec()).or_insert(0);
        let cur = *t;
        *t += 1;
        Ok(if s.prompt() == [1, 2] && cur == 2 {
            vec![7, 8]
        } else {
            vec![0; s.answer_len]
        })
    };
    // pass@3: s1 passes (3rd attempt), s2 fails -> 50%
    let p = pass_at_k_with(&samples, 3, 0.7, 10, &mut sampler).unwrap();
    assert_eq!(p, 50.0);
    // attempt 0 is always greedy (temp 0.0); retries carry the caller's
    // temperature; a passing sample stops sampling (3 calls each here)
    assert_eq!(temps, vec![0.0, 0.7, 0.7, 0.0, 0.7, 0.7]);
    // pass@1 is greedy-only: nothing passes on attempt 0 (fresh sampler)
    let mut greedy_temps: Vec<f32> = Vec::new();
    let mut never = |s: &Sample, temp: f32| -> anyhow::Result<Vec<i32>> {
        greedy_temps.push(temp);
        Ok(vec![-1; s.answer_len])
    };
    let p1 = pass_at_k_with(&samples, 1, 0.7, 10, &mut never).unwrap();
    assert_eq!(p1, 0.0);
    assert_eq!(greedy_temps, vec![0.0, 0.0]);
}

#[test]
fn pass_at_k_greedy_pass_short_circuits() {
    let s = sample(&[5], &[6]);
    let mut calls = 0usize;
    let mut sampler = |s: &Sample, _t: f32| -> anyhow::Result<Vec<i32>> {
        calls += 1;
        Ok(s.answer().to_vec())
    };
    let p = pass_at_k_with(std::slice::from_ref(&s), 5, 0.9, 10, &mut sampler).unwrap();
    assert_eq!((p, calls), (100.0, 1), "a greedy pass must skip the other k-1 attempts");
}

#[test]
fn pass_at_k_edge_cases() {
    // empty samples / max_samples == 0 -> 0.0, sampler never called
    let mut calls = 0usize;
    let mut sampler = |s: &Sample, _t: f32| -> anyhow::Result<Vec<i32>> {
        calls += 1;
        Ok(s.answer().to_vec())
    };
    assert_eq!(pass_at_k_with(&[], 3, 0.7, 10, &mut sampler).unwrap(), 0.0);
    let s = sample(&[5], &[6]);
    assert_eq!(pass_at_k_with(std::slice::from_ref(&s), 3, 0.7, 0, &mut sampler).unwrap(), 0.0);
    assert_eq!(calls, 0);
    // all-wrong sampler -> 0.0 across every attempt
    let samples = vec![sample(&[1], &[2]), sample(&[3], &[4])];
    let mut wrong = |s: &Sample, _t: f32| -> anyhow::Result<Vec<i32>> {
        Ok(vec![-1; s.answer_len])
    };
    assert_eq!(pass_at_k_with(&samples, 4, 0.7, 10, &mut wrong).unwrap(), 0.0);
    // max_samples truncates the denominator: only the first sample counts
    let mut first_only = |s: &Sample, _t: f32| -> anyhow::Result<Vec<i32>> {
        Ok(if s.prompt() == [1] { s.answer().to_vec() } else { vec![-1] })
    };
    assert_eq!(pass_at_k_with(&samples, 1, 0.7, 1, &mut first_only).unwrap(), 100.0);
}

// ---- toy retention proxy ------------------------------------------------

#[test]
fn toy_retention_hand_fixtures() {
    let a = vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0])];
    let mut b = a.clone();
    assert_eq!(toy_retention(&a, &b), 1.0);
    b[0].data[2] = 9.0;
    assert_eq!(toy_retention(&a, &b), 0.75);
    // multiple tensors pool their counts: 1 of 6 weights changed -> 5/6
    let x = vec![
        Tensor::from_vec(&[2], vec![1.0, 2.0]),
        Tensor::from_vec(&[4], vec![0.0, -1.0, 5.0, 2.5]),
    ];
    let mut y = x.clone();
    y[1].data[0] = 0.5;
    assert!((toy_retention(&x, &y) - 5.0 / 6.0).abs() < 1e-12);
    // empty parameter lists trivially retain everything
    assert_eq!(toy_retention(&[], &[]), 1.0);
    // bit identity, not numeric equality: -0.0 != 0.0 bitwise
    let p = vec![Tensor::from_vec(&[1], vec![0.0])];
    let q = vec![Tensor::from_vec(&[1], vec![-0.0])];
    assert_eq!(toy_retention(&p, &q), 0.0);
}

"""Kernel-vs-oracle correctness: the CORE L1 signal.

Each Pallas kernel (interpret=True) must match its pure-jnp oracle in
ref.py across shape/rank/block sweeps (hypothesis where the space is big,
parametrize where it is enumerable).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_matmul,
    flash_attention,
    lowrank_mask,
    lowrank_reconstruct,
    orthonormalize,
    ref,
    sparse_adam_step,
    svd_lowrank,
)
from compile.kernels.sparse_adam import pack_scalars


def rnd(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------- matmul
@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(4, 96),
    k=st.integers(4, 96),
    n=st.integers(4, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rnd(rng, m, k), rnd(rng, k, n)
    got = block_matmul(x, y, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, ref.block_matmul_ref(x, y), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (128, 128, 128), (16, 64, 32)])
def test_block_matmul_block_sweep(blocks):
    rng = np.random.default_rng(0)
    x, y = rnd(rng, 64, 48), rnd(rng, 48, 80)
    bm, bn, bk = blocks
    got = block_matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, x @ y, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------- lowrank mask
@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(8, 128),
    n=st.integers(8, 128),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_mask_matches_ref(m, n, r, seed):
    rng = np.random.default_rng(seed)
    u, v = rnd(rng, m, r), rnd(rng, n, r)
    thr = jnp.asarray([[0.5]], dtype=jnp.float32)
    mask, counts = lowrank_mask(u, v, thr, bm=32, bn=32)
    ref_mask, ref_count = ref.lowrank_mask_ref(u, v, 0.5)
    np.testing.assert_array_equal(mask, ref_mask)
    assert int(jnp.sum(counts)) == int(ref_count)


def test_lowrank_mask_threshold_extremes():
    rng = np.random.default_rng(1)
    u, v = rnd(rng, 32, 4), rnd(rng, 24, 4)
    lo = lowrank_mask(u, v, jnp.zeros((1, 1)))[0]
    assert float(jnp.mean(lo)) == 1.0  # threshold 0 selects everything
    hi = lowrank_mask(u, v, jnp.full((1, 1), 1e9))[0]
    assert float(jnp.mean(hi)) == 0.0


def test_lowrank_reconstruct_matches_product():
    rng = np.random.default_rng(2)
    u, v = rnd(rng, 96, 8), rnd(rng, 72, 8)
    got = lowrank_reconstruct(u, v, bm=32, bn=24)
    np.testing.assert_allclose(got, u @ v.T, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------- sparse adam
@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(8, 3000),
    step=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_adam_matches_ref(k, step, seed):
    rng = np.random.default_rng(seed)
    p, g, m, v = (rnd(rng, k) for _ in range(4))
    v = jnp.abs(v)  # second moment must be nonnegative
    lr, b1, b2, eps, wd = 1e-3, 0.9, 0.999, 1e-8, 0.01
    sc = pack_scalars(lr, b1, b2, eps, wd, step)
    pn, mn, vn = sparse_adam_step(p, g, m, v, sc, bk=256)
    rp, rm, rv = ref.sparse_adam_ref(p, g, m, v, lr, b1, b2, eps, wd, step)
    np.testing.assert_allclose(pn, rp, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mn, rm, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(vn, rv, rtol=1e-5, atol=1e-6)


def test_sparse_adam_zero_grad_is_decay_only():
    k = 64
    p = jnp.ones((k,))
    z = jnp.zeros((k,))
    sc = pack_scalars(0.1, 0.9, 0.999, 1e-8, 0.5, 1)
    pn, mn, vn = sparse_adam_step(p, z, z, z, sc)
    np.testing.assert_allclose(pn, p - 0.1 * 0.5 * p, rtol=1e-6)
    np.testing.assert_allclose(mn, z)


# ------------------------------------------------------- flash attention
@settings(max_examples=10, deadline=None)
@given(
    bh=st.integers(1, 4),
    seq=st.sampled_from([16, 32, 64, 128]),
    dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(bh, seq, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rnd(rng, bh, seq, dh) for _ in range(3))
    got = flash_attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_is_causal():
    # future tokens must not influence earlier outputs
    rng = np.random.default_rng(3)
    q, k, v = (rnd(rng, 2, 32, 16) for _ in range(3))
    o1 = flash_attention(q, k, v)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(-99.0)
    o2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], rtol=1e-5, atol=1e-5)


def test_flash_attention_gradients_match_ref():
    rng = np.random.default_rng(4)
    q, k, v = (rnd(rng, 2, 32, 16) for _ in range(3))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------- subspace svd
def test_orthonormalize_produces_orthonormal_columns():
    rng = np.random.default_rng(5)
    y = rnd(rng, 64, 12)
    q = orthonormalize(y)
    np.testing.assert_allclose(q.T @ q, np.eye(12), atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(24, 128),
    n=st.integers(24, 128),
    r=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_svd_lowrank_error_close_to_exact(m, n, r, seed):
    rng = np.random.default_rng(seed)
    w = rnd(rng, m, r) @ rnd(rng, n, r).T + 0.01 * rnd(rng, m, n)
    g0 = rnd(rng, n, r + 8)
    q, b = svd_lowrank(w, g0, power_iters=2)
    err_rand = float(jnp.linalg.norm(w - q @ b))
    err_exact = float(jnp.linalg.norm(w - ref.svd_lowrank_ref(w, r + 8)))
    assert err_rand <= err_exact * 1.2 + 1e-3


def test_principal_mask_pipeline_against_exact_oracle():
    # end-to-end: randomized factors + threshold kernel vs exact SVD top-k
    rng = np.random.default_rng(6)
    m, n, r, k = 96, 64, 4, 300
    w = rnd(rng, m, r) @ rnd(rng, n, r).T + 0.02 * rnd(rng, m, n)
    g0 = rnd(rng, n, r + 8)
    q, b = svd_lowrank(w, g0, power_iters=3)
    wr = np.asarray(q @ b)
    thr = np.sort(np.abs(wr).ravel())[-k]
    mask, counts = lowrank_mask(q, jnp.asarray(b.T), jnp.full((1, 1), thr))
    exact = np.asarray(ref.principal_mask_ref(w, r + 8, k))
    overlap = float((np.asarray(mask) * exact).sum() / exact.sum())
    assert overlap > 0.9, overlap
    assert abs(int(counts.sum()) - k) <= k * 0.02 + 2

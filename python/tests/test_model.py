"""L2 model correctness: shapes, loss/grad semantics, flash-vs-ref parity,
and the preset/manifest contract the rust side depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    HEAD_DIM,
    PRESETS,
    Preset,
    eval_step,
    forward,
    logits_probe,
    masked_loss,
    rope,
    train_step,
)

TEST_PRESET = Preset("test", d=64, layers=2, ffn=96, vocab=128, seq=16, batch=2)


def make_params(preset, seed=0):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.normal(0, 0.02, s).astype(np.float32))
        for _, s in preset.param_spec()
    ]


def make_batch(preset, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(
        rng.integers(0, preset.vocab, (preset.batch, preset.seq)), dtype=jnp.int32
    )
    tgt = jnp.asarray(
        rng.integers(0, preset.vocab, (preset.batch, preset.seq)), dtype=jnp.int32
    )
    msk = jnp.ones((preset.batch, preset.seq), jnp.float32)
    return tok, tgt, msk


def test_param_spec_order_is_the_contract():
    spec = TEST_PRESET.param_spec()
    assert spec[0][0] == "embed"
    assert spec[-1][0] == "final_norm"
    names = [n for n, _ in spec]
    assert names[1:10] == [
        "l0.attn_norm",
        "l0.wq",
        "l0.wk",
        "l0.wv",
        "l0.wo",
        "l0.mlp_norm",
        "l0.wgate",
        "l0.wup",
        "l0.wdown",
    ]
    assert len(spec) == 2 + 9 * TEST_PRESET.layers


@pytest.mark.parametrize("name", list(PRESETS))
def test_presets_are_consistent(name):
    p = PRESETS[name]
    assert p.d % HEAD_DIM == 0
    assert p.seq % 16 == 0
    # e2e preset is the ~100M model of the e2e example
    if name == "e2e":
        assert 80e6 < p.n_params() < 150e6


def test_forward_shapes_and_finiteness():
    params = make_params(TEST_PRESET)
    tok, _, _ = make_batch(TEST_PRESET)
    logits = forward(params, tok, TEST_PRESET, use_flash=False)
    assert logits.shape == (2, 16, 128)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_flash_and_ref_forward_agree():
    params = make_params(TEST_PRESET)
    tok, _, _ = make_batch(TEST_PRESET)
    lf = forward(params, tok, TEST_PRESET, use_flash=True)
    lr = forward(params, tok, TEST_PRESET, use_flash=False)
    np.testing.assert_allclose(lf, lr, rtol=1e-4, atol=1e-4)


def test_train_step_outputs_loss_plus_all_grads():
    params = make_params(TEST_PRESET)
    tok, tgt, msk = make_batch(TEST_PRESET)
    out = train_step(params, tok, tgt, msk, TEST_PRESET)
    assert len(out) == 1 + len(params)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
    assert float(out[0]) > 0


def test_grads_match_flash_vs_ref():
    params = make_params(TEST_PRESET)
    tok, tgt, msk = make_batch(TEST_PRESET)
    of = train_step(params, tok, tgt, msk, TEST_PRESET, use_flash=True)
    orf = train_step(params, tok, tgt, msk, TEST_PRESET, use_flash=False)
    np.testing.assert_allclose(of[0], orf[0], rtol=1e-5)
    for gf, gr in zip(of[1:], orf[1:]):
        np.testing.assert_allclose(gf, gr, rtol=1e-3, atol=1e-6)


def test_loss_mask_restricts_loss():
    params = make_params(TEST_PRESET)
    tok, tgt, _ = make_batch(TEST_PRESET)
    # mask only position 3; loss must ignore changes elsewhere
    msk = jnp.zeros((2, 16)).at[:, 3].set(1.0)
    l1 = masked_loss(params, tok, tgt, msk, TEST_PRESET, use_flash=False)
    tgt2 = tgt.at[:, 10].set((tgt[:, 10] + 1) % 128)
    l2 = masked_loss(params, tok, tgt2, msk, TEST_PRESET, use_flash=False)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_causality_of_the_full_model():
    params = make_params(TEST_PRESET)
    tok, _, _ = make_batch(TEST_PRESET)
    la = forward(params, tok, TEST_PRESET, use_flash=False)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 5) % 128)
    lb = forward(params, tok2, TEST_PRESET, use_flash=False)
    np.testing.assert_allclose(la[:, :-1], lb[:, :-1], rtol=1e-5, atol=1e-5)


def test_eval_step_preds_are_argmax():
    params = make_params(TEST_PRESET)
    tok, tgt, msk = make_batch(TEST_PRESET)
    loss, preds = eval_step(params, tok, tgt, msk, TEST_PRESET, use_flash=False)
    logits = forward(params, tok, TEST_PRESET, use_flash=False)
    np.testing.assert_array_equal(preds, jnp.argmax(logits, -1).astype(jnp.int32))
    assert float(loss) > 0


def test_logits_probe_is_a_distribution():
    params = make_params(TEST_PRESET)
    tok, _, _ = make_batch(TEST_PRESET)
    probs = logits_probe(params, tok[:1], 5, TEST_PRESET, use_flash=False)
    assert probs.shape == (128,)
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)
    assert float(jnp.min(probs)) >= 0


def test_rope_preserves_norm_and_relative_structure():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 32)).astype(np.float32))
    y = rope(x)
    # rotation preserves per-position norms
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )
    # position 0 is unrotated
    np.testing.assert_allclose(y[:, 0], x[:, 0], rtol=1e-6)


def test_training_reduces_loss():
    # a few SGD steps on a fixed batch must reduce the loss
    params = make_params(TEST_PRESET)
    tok, tgt, msk = make_batch(TEST_PRESET)
    step = jax.jit(
        lambda ps: train_step(ps, tok, tgt, msk, TEST_PRESET, use_flash=False)
    )
    out0 = step(params)
    l0 = float(out0[0])
    for _ in range(10):
        out = step(params)
        params = [p - 0.5 * g for p, g in zip(params, out[1:])]
    assert float(step(params)[0]) < l0

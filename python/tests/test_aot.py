"""AOT interchange contract: HLO text round-trips through the XLA client
(the exact path rust uses), and the manifest agrees with the presets."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import to_hlo_text
from compile.model import PRESETS, Preset, make_lowered

TINY = Preset("unit", d=64, layers=1, ffn=96, vocab=128, seq=16, batch=2)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_and_has_entry():
    low = make_lowered(TINY, "eval_step")
    text = to_hlo_text(low)
    assert "ENTRY" in text and "main" in text
    # parse back (the same entry point rust's from_text_file uses)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


@pytest.mark.parametrize("which", ["train_step", "eval_step", "logits_probe"])
def test_all_graphs_lower(which):
    low = make_lowered(TINY, which)
    text = to_hlo_text(low)
    assert len(text) > 1000
    assert "ENTRY" in text


def test_fixture_expectations_are_stable():
    """Deterministic fixture inputs give finite, reproducible numerics.

    The actual HLO-text -> compile -> execute round-trip is verified on
    the rust side (rust/tests/integration.rs) against the expectations
    emitted by compile.fixtures — that is the real cross-language check.
    """
    from compile import fixtures

    a = fixtures.expectations(TINY)
    b = fixtures.expectations(TINY)
    assert a == b
    assert np.isfinite(a["loss"]) and a["loss"] > 0
    assert len(a["preds_head"]) == 32


def test_fixture_params_formula():
    from compile import fixtures

    params = fixtures.deterministic_params(TINY)
    # spot-check the closed form both languages implement
    w = np.asarray(params[0]).reshape(-1)
    assert abs(w[0] - 0.02 * np.sin(0.0)) < 1e-7
    assert abs(w[5] - 0.02 * np.sin(0.37 * 5)) < 1e-7


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_presets():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as fh:
        man = json.load(fh)
    for name, entry in man["presets"].items():
        preset = PRESETS[name]
        spec = preset.param_spec()
        assert len(entry["params"]) == len(spec)
        for got, (want_name, want_shape) in zip(entry["params"], spec):
            assert got["name"] == want_name
            assert tuple(got["shape"]) == tuple(want_shape)
        for f in entry["executables"].values():
            assert os.path.exists(os.path.join(ARTIFACTS, f)), f
    for f in man["kernels"].values():
        assert os.path.exists(os.path.join(ARTIFACTS, f)), f


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_artifact_hlo_files_parse():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as fh:
        man = json.load(fh)
    some = list(man["kernels"].values())[:3]
    for f in some:
        text = open(os.path.join(ARTIFACTS, f)).read()
        assert xc._xla.hlo_module_from_text(text) is not None

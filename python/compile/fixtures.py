"""Cross-language numerics fixtures.

Both sides construct identical inputs from closed-form formulas (no RNG to
keep in sync), python records the jax-computed expectations in
artifacts/fixtures.json, and rust/tests/integration.rs replays the same
inputs through the compiled artifact and compares. This pins the whole
AOT chain: lowering, text round-trip, rust literal marshalling, execution.
"""

import json
import math
import os

import jax.numpy as jnp
import numpy as np

from .model import PRESETS, eval_step


def deterministic_params(preset):
    """params[t] flat[k] = 0.02 * sin(0.37 k + t) — mirrored in rust."""
    out = []
    for t, (_, shape) in enumerate(preset.param_spec()):
        n = int(np.prod(shape))
        k = np.arange(n, dtype=np.float64)
        vals = 0.02 * np.sin(0.37 * k + t)
        out.append(jnp.asarray(vals.astype(np.float32)).reshape(shape))
    return out


def deterministic_batch(preset):
    """tokens[i] = (7 i + 3) % vocab, targets shifted by 1, full mask."""
    n = preset.batch * preset.seq
    toks = ((7 * np.arange(n) + 3) % preset.vocab).astype(np.int32)
    tgts = ((7 * (np.arange(n) + 1) + 3) % preset.vocab).astype(np.int32)
    msk = np.ones(n, dtype=np.float32)
    shape = (preset.batch, preset.seq)
    return (
        jnp.asarray(toks).reshape(shape),
        jnp.asarray(tgts).reshape(shape),
        jnp.asarray(msk).reshape(shape),
    )


def expectations(preset):
    params = deterministic_params(preset)
    tok, tgt, msk = deterministic_batch(preset)
    loss, preds = eval_step(params, tok, tgt, msk, preset)
    flat = np.asarray(preds).reshape(-1)
    return {
        "loss": float(loss),
        "preds_head": [int(x) for x in flat[:32]],
        "preds_sum": int(flat.astype(np.int64).sum()),
    }


def emit(outdir, preset_names=("tiny",)):
    fix = {name: expectations(PRESETS[name]) for name in preset_names}
    path = os.path.join(outdir, "fixtures.json")
    with open(path, "w") as fh:
        json.dump(fix, fh, indent=1)
    print(f"  wrote fixtures.json ({list(fix)})")
    return path

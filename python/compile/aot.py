"""AOT compiler: lower every L1/L2 graph to HLO text + write the manifest.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (in --outdir, default ../artifacts):
    <preset>.train_step.hlo.txt     loss + full grads
    <preset>.eval_step.hlo.txt      loss + greedy predictions
    <preset>.logits_probe.hlo.txt   next-token distribution probe (Fig 2b)
    svd_<m>x<n>_r<rp>.hlo.txt       subspace-iteration factors (q, b)
    mask_<m>x<n>_r<rp>.hlo.txt      fused lowrank reconstruct+threshold mask
    sparse_adam_<k>.hlo.txt         packed AdamW step, bucketed k
    manifest.json                   the rust-side contract (shapes, order)

Run: ``cd python && python -m compile.aot --outdir ../artifacts``
(idempotent; the Makefile skips it when inputs are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import PRESETS, make_lowered
from .kernels.lowrank_mask import lowrank_mask
from .kernels.sparse_adam import sparse_adam_step
from .kernels.subspace_iter import svd_lowrank

# LoRA-rank-equivalent ranks the canonical artifacts are built for; other
# ranks run through the rust XlaBuilder path (runtime/linalg.rs), which is
# cross-checked against these artifacts in rust/tests/.
KERNEL_RANKS = (32, 128)
OVERSAMPLE = 8
POWER_ITERS = 2
ADAM_BUCKETS = (4096, 16384, 65536, 262144, 1048576)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(outdir, name, text):
    path = os.path.join(outdir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name}  ({len(text) / 1e6:.2f} MB)")
    return name


def lower_kernels(outdir, shapes):
    """SVD + mask kernels per distinct trainable-matrix shape and rank."""
    entries = {}
    for (m, n) in sorted(shapes):
        for r in KERNEL_RANKS:
            rp = r + OVERSAMPLE
            if rp > min(m, n):
                continue
            w = jax.ShapeDtypeStruct((m, n), jnp.float32)
            g0 = jax.ShapeDtypeStruct((n, rp), jnp.float32)
            low = jax.jit(
                lambda w, g0: svd_lowrank(w, g0, power_iters=POWER_ITERS)
            ).lower(w, g0)
            name = f"svd_{m}x{n}_r{rp}"
            entries[name] = _write(outdir, name + ".hlo.txt", to_hlo_text(low))

            u = jax.ShapeDtypeStruct((m, rp), jnp.float32)
            v = jax.ShapeDtypeStruct((n, rp), jnp.float32)
            thr = jax.ShapeDtypeStruct((1, 1), jnp.float32)
            low = jax.jit(lambda u, v, t: lowrank_mask(u, v, t)).lower(u, v, thr)
            name = f"mask_{m}x{n}_r{rp}"
            entries[name] = _write(outdir, name + ".hlo.txt", to_hlo_text(low))
    return entries


def lower_sparse_adam(outdir):
    entries = {}
    for k in ADAM_BUCKETS:
        vec = jax.ShapeDtypeStruct((k,), jnp.float32)
        sc = jax.ShapeDtypeStruct((1, 8), jnp.float32)
        low = jax.jit(sparse_adam_step).lower(vec, vec, vec, vec, sc)
        name = f"sparse_adam_{k}"
        entries[name] = _write(outdir, name + ".hlo.txt", to_hlo_text(low))
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,small,base",
        help="comma list; 'e2e' (~100M params) is built on demand by "
        "`make artifacts-e2e`",
    )
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    mpath = os.path.join(args.outdir, "manifest.json")
    manifest = {"presets": {}, "kernels": {}, "adam_buckets": list(ADAM_BUCKETS)}
    if os.path.exists(mpath):
        # incremental: keep already-built presets not in this invocation
        with open(mpath) as fh:
            old = json.load(fh)
        manifest["presets"] = old.get("presets", {})
        manifest["kernels"] = old.get("kernels", {})

    shapes = set()
    for pname in [p for p in args.presets.split(",") if p]:
        preset = PRESETS[pname]
        print(f"preset {pname}: ~{preset.n_params() / 1e6:.1f}M params")
        execs = {}
        for which in ("train_step", "eval_step", "logits_probe"):
            low = make_lowered(preset, which)
            execs[which] = _write(
                args.outdir, f"{pname}.{which}.hlo.txt", to_hlo_text(low)
            )
        manifest["presets"][pname] = {
            "d": preset.d,
            "layers": preset.layers,
            "ffn": preset.ffn,
            "vocab": preset.vocab,
            "seq": preset.seq,
            "batch": preset.batch,
            "heads": preset.heads,
            "params": [
                {"name": n, "shape": list(s)} for n, s in preset.param_spec()
            ],
            "executables": execs,
        }
        d, f = preset.d, preset.ffn
        shapes |= {(d, d), (d, f), (f, d)}

    if not args.skip_kernels:
        manifest["kernels"].update(lower_kernels(args.outdir, shapes))
        manifest["kernels"].update(lower_sparse_adam(args.outdir))
        manifest["kernel_ranks"] = list(KERNEL_RANKS)
        manifest["oversample"] = OVERSAMPLE
        manifest["power_iters"] = POWER_ITERS

    with open(mpath, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    print(f"wrote {mpath}")

    # cross-language numerics fixtures (rust/tests/integration.rs)
    from . import fixtures

    fixture_presets = [p for p in ("tiny",) if p in manifest["presets"]]
    if fixture_presets:
        fixtures.emit(args.outdir, fixture_presets)


if __name__ == "__main__":
    main()

"""Layer-2: the JAX transformer (fwd/bwd) that the rust coordinator drives.

A LLaMA-shaped decoder-only LM — pre-norm RMSNorm, rotary attention, SwiGLU
MLP, tied embeddings — so the paper's per-layer-type analyses (Query / Key /
Value / Output / Gate / Up / Down) transfer verbatim. Attention routes
through the Pallas flash kernel (kernels.flash_attn) with a recompute VJP,
so ``jax.grad`` lowers kernel + model into one HLO module.

Parameter order is the interchange contract with rust (model/preset.rs):

    embed (V, d)
    per layer l in 0..L:
        attn_norm (d,)
        wq (d, d)   wk (d, d)   wv (d, d)   wo (d, d)
        mlp_norm (d,)
        wgate (d, f)   wup (d, f)   wdown (f, d)
    final_norm (d,)

Everything is f32; matrices are stored (in, out) and applied as ``x @ W``.
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.flash_attn import flash_attention
from .kernels.ref import attention_ref

HEAD_DIM = 64


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    d: int
    layers: int
    ffn: int
    vocab: int
    seq: int
    batch: int

    @property
    def heads(self) -> int:
        assert self.d % HEAD_DIM == 0
        return self.d // HEAD_DIM

    def param_spec(self):
        """[(name, shape)] in canonical interchange order."""
        spec = [("embed", (self.vocab, self.d))]
        for l in range(self.layers):
            spec += [
                (f"l{l}.attn_norm", (self.d,)),
                (f"l{l}.wq", (self.d, self.d)),
                (f"l{l}.wk", (self.d, self.d)),
                (f"l{l}.wv", (self.d, self.d)),
                (f"l{l}.wo", (self.d, self.d)),
                (f"l{l}.mlp_norm", (self.d,)),
                (f"l{l}.wgate", (self.d, self.ffn)),
                (f"l{l}.wup", (self.d, self.ffn)),
                (f"l{l}.wdown", (self.ffn, self.d)),
            ]
        spec.append(("final_norm", (self.d,)))
        return spec

    def n_params(self) -> int:
        return sum(
            int(jnp.prod(jnp.array(s, dtype=jnp.int64))) for _, s in self.param_spec()
        )


# Sized for a 1-core CPU box (DESIGN.md §3): `tiny`/`small` drive the
# experiment tables, `base` the analyses, `e2e` is the ~100M-param preset
# for the end-to-end example.
PRESETS = {
    "tiny": Preset("tiny", d=128, layers=4, ffn=352, vocab=512, seq=64, batch=16),
    "small": Preset("small", d=256, layers=6, ffn=704, vocab=1024, seq=64, batch=8),
    "base": Preset("base", d=384, layers=8, ffn=1024, vocab=4096, seq=128, batch=8),
    "e2e": Preset("e2e", d=768, layers=12, ffn=2048, vocab=16384, seq=256, batch=4),
}


def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x):
    """Rotary embedding over (B, S, H, hd)."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(s, dtype=jnp.float32)
    ang = t[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, use_flash):
    """(B, S, H, hd) -> (B, S, H, hd), causal."""
    b, s, h, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    unfold = lambda t: t.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    if use_flash:
        return unfold(flash_attention(fold(q), fold(k), fold(v)))
    return unfold(attention_ref(fold(q), fold(k), fold(v)))


def forward(params, tokens, preset: Preset, use_flash=True):
    """Token ids (B, S) -> logits (B, S, V)."""
    p = list(params)
    embed = p[0]
    final_norm = p[-1]
    x = jnp.take(embed, tokens, axis=0)  # (B, S, d)
    b, s, d = x.shape
    h = preset.heads
    for l in range(preset.layers):
        base = 1 + 9 * l
        attn_norm, wq, wk, wv, wo, mlp_norm, wgate, wup, wdown = p[base : base + 9]
        hpre = rmsnorm(x, attn_norm)
        q = rope((hpre @ wq).reshape(b, s, h, HEAD_DIM))
        k = rope((hpre @ wk).reshape(b, s, h, HEAD_DIM))
        v = (hpre @ wv).reshape(b, s, h, HEAD_DIM)
        o = _attention(q, k, v, use_flash).reshape(b, s, d)
        x = x + o @ wo
        hpre = rmsnorm(x, mlp_norm)
        x = x + (jax.nn.silu(hpre @ wgate) * (hpre @ wup)) @ wdown
    x = rmsnorm(x, final_norm)
    return x @ embed.T


def masked_loss(params, tokens, targets, loss_mask, preset, use_flash=True):
    """Mean masked cross-entropy (next-token targets pre-shifted by host)."""
    logits = forward(params, tokens, preset, use_flash)
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll * loss_mask) / denom


def train_step(params, tokens, targets, loss_mask, preset, use_flash=True):
    """-> (loss, grad_0, ..., grad_{P-1}) in param_spec order."""
    loss, grads = jax.value_and_grad(
        lambda ps: masked_loss(ps, tokens, targets, loss_mask, preset, use_flash)
    )(list(params))
    return (loss, *grads)


def eval_step(params, tokens, targets, loss_mask, preset, use_flash=True):
    """-> (loss, preds (B, S) i32): loss + greedy predictions."""
    logits = forward(params, tokens, preset, use_flash)
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    loss = jnp.sum(nll * loss_mask) / denom
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return loss, preds


def logits_probe(params, tokens, pos, preset, use_flash=True):
    """-> (V,) next-token distribution at position `pos` of row 0 (Fig 2b)."""
    logits = forward(params, tokens, preset, use_flash)
    return jax.nn.softmax(logits[0, pos], axis=-1)


def make_lowered(preset: Preset, which: str, use_flash=None):
    """Lower one graph with this preset's static shapes (aot.py entry).

    Per-backend attention choice (§Perf): the *train* graph keeps the
    Pallas flash kernel (the architecture contribution; wins on TPU where
    the kernel is compiled for the MXU). The no-grad eval/probe graphs
    default to the materializing attention, which is ~1.3x faster under
    interpret-lowered HLO on CPU at our sequence lengths.
    """
    if use_flash is None:
        use_flash = which == "train_step"
    P = preset.param_spec()
    pspecs = tuple(jax.ShapeDtypeStruct(s, jnp.float32) for _, s in P)
    tok = jax.ShapeDtypeStruct((preset.batch, preset.seq), jnp.int32)
    msk = jax.ShapeDtypeStruct((preset.batch, preset.seq), jnp.float32)
    if which == "train_step":
        fn = lambda *a: train_step(a[: len(P)], a[-3], a[-2], a[-1], preset, use_flash)
        args = (*pspecs, tok, tok, msk)
    elif which == "eval_step":
        fn = lambda *a: eval_step(a[: len(P)], a[-3], a[-2], a[-1], preset, use_flash)
        args = (*pspecs, tok, tok, msk)
    elif which == "logits_probe":
        tok1 = jax.ShapeDtypeStruct((1, preset.seq), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = lambda *a: (logits_probe(a[: len(P)], a[-2], a[-1], preset, use_flash),)
        args = (*pspecs, tok1, pos)
    else:
        raise ValueError(which)
    return jax.jit(fn).lower(*args)

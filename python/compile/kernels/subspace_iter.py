"""Truncated SVD by blocked randomized subspace iteration.

The HLO-text interchange (see DESIGN.md §3) cannot carry LAPACK
custom-calls, so ``jnp.linalg.svd`` is off the table for anything that must
execute from rust. This module builds the rank-r approximation from pure
matmuls — which is exactly what the MXU wants anyway:

    G0 ~ N(0, 1) (n, r+p)                      (host-supplied, fixed seed)
    Y  = W G;  Q = orth(Y)                      range finder
    repeat q times:  Q = orth(W orth(W^T Q))    power iterations
    B  = Q^T W  (r+p, n)                        projection
    top-r of W  ~=  Q[:, :r] B[:r, :]           (after small-side rotation)

Orthonormalization is LAPACK-free too: ``orth(Y) = Y (Y^T Y + eps I)^{-1/2}``
with the inverse square root of the small (r+p, r+p) Gram matrix computed by
a Newton–Schulz iteration (matmuls only, quadratic convergence).

The small-side rotation diagonalizes B B^T with a Jacobi sweep *on the
host at build time only* — at runtime rust mirrors this with its own Jacobi
eigensolver (util/eigh.rs). For mask selection the rotation is optional:
the mask depends on Q Q^T W which is rotation-invariant.

The heavy products W G / W^T Q go through the ``block_matmul`` Pallas
kernel, so the whole factorization lowers into MXU-tiled HLO.
"""

import functools

import jax
import jax.numpy as jnp

from .block_matmul import block_matmul

_NEWTON_ITERS = 24
# trace-relative ridge: keeps Newton-Schulz inside its convergence domain
# even when Y is rank-deficient (true rank < rank + oversample).
_EPS_REL = 1e-6


def invsqrt_psd(a, iters=_NEWTON_ITERS):
    """(A + eps I)^{-1/2} for small PSD A via coupled Newton–Schulz.

    Denman–Beavers style coupling: Y -> A^{1/2}, Z -> A^{-1/2}; scaled so
    the initial spectral radius is < sqrt(3) (convergence domain).
    """
    r = a.shape[0]
    eye = jnp.eye(r, dtype=a.dtype)
    a = a + (_EPS_REL * jnp.trace(a) + 1e-30) * eye
    # trace bound: ||A||_2 <= tr(A), cheap and safe for PSD
    c = jnp.trace(a)
    y = a / c
    z = eye

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * eye - z @ y)
        return y @ t, t @ z

    y, z = jax.lax.fori_loop(0, iters, body, (y, z))
    return z / jnp.sqrt(c)


def _orth_once(y):
    g = jax.lax.dot_general(
        y, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    return y @ invsqrt_psd(g)


def orthonormalize(y):
    """Column-orthonormalize via Gram inverse square root (matmul only).

    Two passes: the second repairs the residual non-orthogonality the ridge
    leaves behind when Y is rank-deficient (standard randomized-SVD trick).
    """
    return _orth_once(_orth_once(y))


@functools.partial(jax.jit, static_argnames=("power_iters", "use_pallas"))
def svd_lowrank(w, g0, *, power_iters=2, use_pallas=True):
    """Rank-(r+p) factors of w: returns (q, b) with w ~= q @ b.

    Args:
      w: (m, n) matrix.
      g0: (n, r+p) gaussian test matrix (host-seeded for determinism).
      power_iters: number of (W W^T) power iterations (accuracy knob).
      use_pallas: route the large matmuls through the Pallas tile kernel.

    Returns:
      q: (m, r+p) orthonormal range basis.
      b: (r+p, n) projection Q^T W.
    """
    mm = block_matmul if use_pallas else (lambda x, y: x @ y)
    y = mm(w, g0)  # (m, r+p)
    q = orthonormalize(y)
    for _ in range(power_iters):
        z = orthonormalize(mm(w.T, q))  # (n, r+p)
        q = orthonormalize(mm(w, z))
    b = mm(q.T, w)  # (r+p, n)
    return q, b

"""Fused low-rank reconstruct + threshold mask — the LIFT selection kernel.

LIFT needs the binary mask ``M = |W'| >= t`` where ``W' = U @ V^T`` is the
rank-r approximation of a weight matrix (U already folds the singular
values). A naive implementation materializes W' (m*n floats) in HBM, then
runs a global top-k. On TPU this kernel instead:

  * tiles U into (bm, r) and V into (bn, r) VMEM blocks via BlockSpec,
  * reconstructs one (bm, bn) tile of W' on the MXU,
  * applies |.| >= t on the VPU and writes only the (bit-sized) mask tile
    plus a per-tile popcount.

HBM traffic is (m + n) * r * 4B for the factors (read once per grid row /
column) + m*n mask bytes out, instead of m*n*4B*2 for the materializing
path. The per-tile counts let the host run a 2-pass threshold bisection to
hit an exact k without a global sort.

VMEM footprint per grid step: (bm*r + bn*r + bm*bn) * 4B; with the default
bm = bn = 128 and r <= 256 that is (128*256*2 + 128*128)*4B = 320 KiB,
far under the ~16 MiB VMEM budget, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask_kernel(u_ref, v_ref, thr_ref, mask_ref, cnt_ref):
    u = u_ref[...]  # (bm, r)  VMEM
    v = v_ref[...]  # (bn, r)  VMEM
    # MXU: one (bm, bn) tile of W' = U V^T. f32 accumulate.
    w = jax.lax.dot_general(
        u, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a = jnp.abs(w)
    t = thr_ref[0, 0]
    m = (a >= t).astype(jnp.float32)  # VPU compare
    mask_ref[...] = m
    cnt_ref[0, 0] = jnp.sum(m).astype(jnp.int32)


def _recon_kernel(u_ref, v_ref, out_ref):
    u = u_ref[...]
    v = v_ref[...]
    out_ref[...] = jax.lax.dot_general(
        u, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _pick(block, dim):
    """Largest tile <= block that divides dim (keeps the grid exact)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _grid_dims(m, n, bm, bn):
    return m // bm, n // bn


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lowrank_mask(u, v, thr, *, bm=128, bn=128):
    """Binary mask of |U @ V^T| >= thr, plus per-tile counts.

    Args:
      u: (m, r) left factor (singular values folded in).
      v: (n, r) right factor.
      thr: (1, 1) threshold.
      bm, bn: tile sizes (VMEM schedule).

    Returns:
      mask: (m, n) f32 in {0, 1}.
      counts: (gm, gn) i32 per-tile popcounts.
    """
    m, r = u.shape
    n, _ = v.shape
    bm = _pick(bm, m)
    bn = _pick(bn, n)
    gm, gn = _grid_dims(m, n, bm, bn)
    return pl.pallas_call(
        _mask_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        ],
        interpret=True,
    )(u, v, thr)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lowrank_reconstruct(u, v, *, bm=128, bn=128):
    """Materialize W' = U @ V^T tile by tile (host top-k path)."""
    m, r = u.shape
    n, _ = v.shape
    bm = _pick(bm, m)
    bn = _pick(bn, n)
    gm, gn = _grid_dims(m, n, bm, bn)
    return pl.pallas_call(
        _recon_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(u, v)

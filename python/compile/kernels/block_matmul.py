"""Tiled matmul kernel — the MXU workhorse for the truncated-SVD path.

Grid is (m/bm, n/bn, k/bk); the k axis is the innermost (sequential) grid
dimension so each (i, j) output tile stays resident in VMEM while partial
products accumulate — the BlockSpec expresses the HBM->VMEM schedule a GPU
implementation would write with threadblocks + shared-memory staging.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pick(block, dim):
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def block_matmul(x, y, *, bm=128, bn=128, bk=128):
    """x (m, k) @ y (k, n) with explicit MXU tiling."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)

"""Causal flash attention (forward) as a Pallas kernel + custom VJP.

The model's fwd hot-spot. TPU adaptation of the FlashAttention schedule:
instead of a threadblock per (head, q-tile) staging K/V through shared
memory, the BlockSpec grid is (batch*heads, q-tiles); K and V for the head
live in VMEM (seq <= 512 in our presets, so S*dh*4B <= 128 KiB) and the
kernel streams kv-tiles with an online-softmax carry (m, l, acc) in
registers/VMEM — numerically identical to materializing the (S, S) score
matrix but with O(bq * S) live memory instead of O(S^2).

Backward is a recompute VJP in plain jnp (the classic memory/compute trade:
nothing but q, k, v is saved), so ``jax.grad`` through the model lowers the
Pallas forward into the same HLO module as the rest of the train step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bkv, seq, scale):
    qi = pl.program_id(1)
    q = q_ref[0] * scale  # (bq, dh)
    dh = q.shape[-1]
    qpos = qi * bq + jax.lax.iota(jnp.int32, bq)  # absolute query rows

    def body(j, carry):
        acc, m_i, l_i = carry
        k_blk = jax.lax.dynamic_slice(k_ref[0], (j * bkv, 0), (bkv, dh))
        v_blk = jax.lax.dynamic_slice(v_ref[0], (j * bkv, 0), (bkv, dh))
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bkv)
        kpos = j * bkv + jax.lax.iota(jnp.int32, bkv)
        causal = qpos[:, None] >= kpos[None, :]
        s = jnp.where(causal, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc, m_new, l_new

    acc = jnp.zeros((bq, dh), jnp.float32)
    m_i = jnp.full((bq,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((bq,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, seq // bkv, body, (acc, m_i, l_i))
    o_ref[0] = acc / l_i[:, None]


@functools.partial(jax.jit, static_argnames=("bq", "bkv"))
def _flash_fwd(q, k, v, *, bq=128, bkv=128):
    bh, seq, dh = q.shape
    bq = min(bq, seq)
    bkv = min(bkv, seq)
    assert seq % bq == 0 and seq % bkv == 0, (seq, bq, bkv)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(
        _attn_fwd_kernel, bq=bq, bkv=bkv, seq=seq, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, seq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def _attn_ref(q, k, v):
    """Materializing causal attention (used by the recompute backward)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (dh**0.5)
    seq = q.shape[1]
    causal = jnp.tril(jnp.ones((seq, seq), bool))
    s = jnp.where(causal[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p, jnp.einsum("bqk,bkd->bqd", p, v)


@jax.custom_vjp
def flash_attention(q, k, v):
    """Causal attention over (batch*heads, seq, head_dim)."""
    return _flash_fwd(q, k, v)


def _vjp_fwd(q, k, v):
    return _flash_fwd(q, k, v), (q, k, v)


def _vjp_bwd(res, do):
    q, k, v = res
    dh = q.shape[-1]
    scale = 1.0 / (dh**0.5)
    p, _ = _attn_ref(q, k, v)
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)

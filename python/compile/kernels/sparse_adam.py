"""Packed sparse AdamW step — Algorithm 1 (lines 13-18) of the paper.

LIFT stores optimizer state only for masked ("principal") weights, packed
into contiguous vectors of length k. That packing is what makes the state
VPU-friendly: a GPU implementation scatters through irregular indices; here
the gather/scatter lives at the mask boundary (host / L3) and the optimizer
math streams over dense lanes.

All scalars (lr, betas, eps, weight decay, bias corrections) arrive in one
(1, 8) SMEM-style block so a single executable serves every step t — the
host precomputes 1 - beta^t.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# scalar slot layout in the (1, 8) control block
LR, B1, B2, EPS, WD, BC1, BC2, _PAD = range(8)


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, s_ref, po_ref, mo_ref, vo_ref):
    g = g_ref[...]
    p = p_ref[...]
    lr = s_ref[0, LR]
    b1 = s_ref[0, B1]
    b2 = s_ref[0, B2]
    eps = s_ref[0, EPS]
    wd = s_ref[0, WD]
    bc1 = s_ref[0, BC1]
    bc2 = s_ref[0, BC2]

    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    update = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    po_ref[...] = p - lr * update
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("bk",))
def sparse_adam_step(p, g, m, v, scalars, *, bk=4096):
    """One AdamW step over packed principal-weight vectors.

    Args:
      p, g, m, v: (k,) packed params / grads / first / second moments.
      scalars: (1, 8) [lr, b1, b2, eps, wd, 1-b1^t, 1-b2^t, pad].

    Returns:
      (p_new, m_new, v_new), each (k,).
    """
    (k,) = p.shape
    bk = min(bk, k)
    while k % bk:
        bk -= 1
    grid = (k // bk,)
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(p, g, m, v, scalars)


def pack_scalars(lr, b1, b2, eps, wd, step):
    """Host-side helper mirrored in rust (runtime/sparse_adam.rs)."""
    return jnp.array(
        [[lr, b1, b2, eps, wd, 1.0 - b1**step, 1.0 - b2**step, 0.0]],
        dtype=jnp.float32,
    )

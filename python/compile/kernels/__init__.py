"""Layer-1 Pallas kernels for LIFT.

Every kernel here is authored for TPU semantics (BlockSpec = HBM->VMEM
schedule, MXU-shaped matmul tiles, VPU elementwise lanes) and lowered with
``interpret=True`` so the resulting HLO runs on the CPU PJRT client (real
TPU lowering emits Mosaic custom-calls the CPU plugin cannot execute).

Kernels:
  - lowrank_mask:   fused rank-r reconstruct + |.| >= threshold mask + count
                    (the LIFT principal-weight selection hot-spot; never
                    materializes W' in HBM)
  - block_matmul:   tiled matmul used by the truncated-SVD subspace iteration
  - sparse_adam:    packed sparse AdamW step (Algorithm 1, lines 13-18)
  - flash_attn:     causal tiled attention with online softmax (model fwd)

``ref.py`` carries the pure-jnp oracles; pytest + hypothesis sweep shapes
and assert allclose.
"""

from . import ref  # noqa: F401
from .lowrank_mask import lowrank_mask, lowrank_reconstruct  # noqa: F401
from .block_matmul import block_matmul  # noqa: F401
from .sparse_adam import sparse_adam_step  # noqa: F401
from .flash_attn import flash_attention  # noqa: F401
from .subspace_iter import svd_lowrank, orthonormalize  # noqa: F401

"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

pytest (python/tests/) asserts kernel-vs-ref allclose across hypothesis
shape/rank sweeps; these functions are also the spec the rust side mirrors
(rust/tests/ cross-checks runtime numerics against values exported here).
"""

import jax
import jax.numpy as jnp


def lowrank_mask_ref(u, v, thr):
    """Mask + count oracle for kernels.lowrank_mask (whole-matrix)."""
    w = u @ v.T
    mask = (jnp.abs(w) >= thr).astype(jnp.float32)
    return mask, jnp.sum(mask).astype(jnp.int32)


def lowrank_reconstruct_ref(u, v):
    return u @ v.T


def block_matmul_ref(x, y):
    return x @ y


def sparse_adam_ref(p, g, m, v, lr, b1, b2, eps, wd, step):
    """AdamW oracle matching kernels.sparse_adam_step semantics."""
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    mhat = m_new / (1.0 - b1**step)
    vhat = v_new / (1.0 - b2**step)
    p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p_new, m_new, v_new


def attention_ref(q, k, v):
    """Causal softmax attention over (bh, seq, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (dh**0.5)
    seq = q.shape[1]
    causal = jnp.tril(jnp.ones((seq, seq), bool))
    s = jnp.where(causal[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def svd_lowrank_ref(w, r):
    """Exact rank-r approximation via LAPACK (build-time oracle only)."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r]


def principal_mask_ref(w, r, k):
    """End-to-end LIFT selection oracle: exact SVD_r -> top-k magnitude."""
    wr = svd_lowrank_ref(w, r)
    flat = jnp.abs(wr).reshape(-1)
    thr = jnp.sort(flat)[-k]
    return (jnp.abs(wr) >= thr).astype(jnp.float32)
